#!/usr/bin/env python
"""Render (or validate) an observability run log (repro.obs JSONL).

Summary mode prints the run's trajectories — loss, exact wire bytes,
energy/carbon, Sophia health probes — plus the staleness histogram and
host-span timing aggregates, straight from the structured records:

    python tools/obs_report.py runs/fed.jsonl

Validation mode (`--validate`, the `make obs-smoke` /
`make bench-records-check` CI gate) checks the manifest header,
re-validates every record against the frozen schema
(repro.obs.schema), and requires at least one content record:

    python tools/obs_report.py runs/fed.jsonl --validate

Degenerate logs — missing file, empty file, a truncated final JSONL
line (a live or killed run), a missing manifest — produce a one-line
diagnosis and a nonzero exit, never a traceback (tested in
tests/test_obs_tools.py).  Logs from older supported schema versions
(`repro.obs.schema.SUPPORTED_SCHEMA_VERSIONS`) validate without the
fingerprint check; only a current-version manifest must match this
checkout's registry byte-for-byte.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro import obs  # noqa: E402

#: records that carry a per-aggregation trajectory point
TRAJECTORY = ("round", "sched_event")
#: record types that count as "this log has content"
CONTENT = TRAJECTORY + ("bench", "serve")


def load(path: str):
    """Tolerant record load (`repro.obs.logio`); exits with the
    reader's one-line diagnosis instead of a traceback."""
    try:
        return obs.read_records(path)
    except obs.ObsLogError as e:
        raise SystemExit(str(e))


def validate(path: str, records) -> int:
    errors = []
    first = records[0]
    if first.get("record") != "manifest":
        errors.append(
            "line 1: first record must be the run manifest — is this "
            "a legacy pre-schema file?  Regenerate it through "
            "repro.obs.RunRecorder")
    else:
        ver = first.get("schema_version")
        if ver not in obs.SUPPORTED_SCHEMA_VERSIONS:
            errors.append(
                f"manifest: schema_version {ver} is not supported by "
                f"this checkout (want one of "
                f"{list(obs.SUPPORTED_SCHEMA_VERSIONS)})")
        elif (ver == obs.SCHEMA_VERSION
              and first.get("schema_sha256") != obs.fingerprint()):
            errors.append(
                "manifest: schema_sha256 does not match this checkout's "
                "metric registry (repro.obs.schema) — log and code "
                "disagree about what the columns mean")
    counts: dict = defaultdict(int)
    for i, rec in enumerate(records):
        try:
            obs.validate_record(rec)
            counts[rec["record"]] += 1
        except obs.ObsSchemaError as e:
            errors.append(f"record {i + 1}: {e}")
    if not any(counts[k] for k in CONTENT):
        errors.append(
            "no content records (`round`, `sched_event`, `bench` or "
            "`serve`) — the log carries no trajectory or results")
    if errors:
        print(f"{path}: INVALID ({len(errors)} error(s))")
        for e in errors[:20]:
            print(f"  {e}")
        return 1
    print(f"{path}: valid — "
          + ", ".join(f"{v} {k}" for k, v in sorted(counts.items())
                      if v))
    return 0


def _fmt_bytes(n) -> str:
    return f"{n / (1 << 20):.2f}MiB"


def _traj_row(rec) -> str:
    idx = rec.get("round", rec.get("version", "?"))
    cum = rec.get("cum_total_bytes", 0)
    cols = [f"loss={rec.get('loss', float('nan')):.4f}",
            f"cum={_fmt_bytes(cum)}"]
    if "eval_loss" in rec:
        cols.append(f"eval={rec['eval_loss']:.4f}")
    if "energy_J" in rec:
        cols.append(f"E={rec['energy_J']:.3g}J")
    if "carbon_kg" in rec:
        cols.append(f"CO2={rec['carbon_kg']:.3g}kg")
    for probe in ("clip_fraction", "m_norm", "h_norm"):
        if probe in rec:
            cols.append(f"{probe.split('_')[0]}={rec[probe]:.3g}")
    if "h_staleness" in rec:
        cols.append(f"stale_h={rec['h_staleness']:.0f}")
    return f"  {idx:>5}  " + "  ".join(cols)


def summarize(path: str, records) -> int:
    by_kind: dict = defaultdict(list)
    for rec in records:
        by_kind[rec.get("record", "?")].append(rec)

    if by_kind.get("manifest"):
        man = by_kind["manifest"][0]
        meta = man.get("meta", {})
        print(f"{path}: schema v{man.get('schema_version', '?')}"
              + (f" — {json.dumps(meta, sort_keys=True)}" if meta else ""))
    else:
        print(f"{path}: no manifest record (legacy or hand-written "
              f"log) — rendering best-effort")

    traj = [r for k in TRAJECTORY for r in by_kind.get(k, [])]
    if traj:
        print(f"\ntrajectory ({len(traj)} aggregation events):")
        shown = traj if len(traj) <= 12 else traj[:6] + traj[-6:]
        for i, rec in enumerate(shown):
            if len(traj) > 12 and i == 6:
                print(f"  ... {len(traj) - 12} more ...")
            print(_traj_row(rec))
    elif not (by_kind.get("bench") or by_kind.get("serve")):
        print("\nno trajectory records (`round`/`sched_event`) — "
              "an empty or setup-only run")

    ndisp = len(by_kind.get("sched_dispatch", []))
    if ndisp:
        print(f"\ntrace contexts: {ndisp} dispatches "
              f"(export with tools/obs_trace.py)")

    for summ in by_kind.get("sched_summary", []):
        hist = dict(summ.get("staleness_hist", []))
        print(f"\nscheduler: {summ['discipline']}, {summ['events']} events, "
              f"simulated {summ['final_time_s']:.2f}s, "
              f"{_fmt_bytes(summ['cum_total_bytes'])} on the wire")
        if hist:
            print("staleness histogram: "
                  + "  ".join(f"{k}:{v}" for k, v in sorted(hist.items())))

    bench = by_kind.get("bench", [])
    if bench:
        print(f"\nbench rows ({len(bench)}):")
        for r in bench:
            cols = [f"{k}={r[k]}" for k in
                    ("layout_ops", "us_per_round", "total_bytes",
                     "reduction_x", "speedup_x") if k in r]
            print(f"  {r.get('name', '?'):<40} " + "  ".join(cols))

    serve = by_kind.get("serve", [])
    if serve:
        last = serve[-1]
        print(f"\nserving ({len(serve)} samples): last "
              f"{last['tokens_per_s']:.1f} tok/s, batch {last['batch']}, "
              f"prefill {last['prefill_s'] * 1e3:.1f}ms"
              + (f", decode p50/p95/p99 {last['decode_p50_ms']:.2f}/"
                 f"{last['decode_p95_ms']:.2f}/"
                 f"{last['decode_p99_ms']:.2f}ms"
                 if "decode_p50_ms" in last else ""))

    spans = by_kind.get("span", [])
    if spans:
        agg: dict = defaultdict(lambda: [0, 0.0])
        for s in spans:
            agg[s["name"]][0] += 1
            agg[s["name"]][1] += s["wall_s"]
        print("\nhost spans (wall-clock):")
        for name, (n, total) in sorted(agg.items(),
                                       key=lambda kv: -kv[1][1]):
            print(f"  {name:<12} n={n:<5} total={total:.3f}s "
                  f"mean={total / n * 1e3:.1f}ms")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("log", help="JSONL run log written by --obs-log")
    ap.add_argument("--validate", action="store_true",
                    help="schema-validate every record and exit nonzero "
                         "on the first structural problem (CI mode)")
    args = ap.parse_args()
    records = load(args.log)
    if args.validate:
        return validate(args.log, records)
    return summarize(args.log, records)


if __name__ == "__main__":
    sys.exit(main())
