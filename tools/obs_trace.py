#!/usr/bin/env python
"""Export an obs run log as Chrome Trace Event / Perfetto JSON.

Reads a JSONL run log that was written with tracing on
(``--trace`` in `repro.launch.train`, or ``ObsConfig.trace``) and
renders the whole run as a trace you can open in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``: one lane per
client with per-stream transfer slices sized by the exact byte
counters, a server apply lane, and counter tracks for loss and the
Sophia health probes.

    python tools/obs_trace.py runs/fed.jsonl --out trace.json
    python tools/obs_trace.py runs/fed.jsonl --validate

``--validate`` (the `make obs-trace-smoke` CI gate) structurally
validates the export — required keys per event, non-negative
durations, non-decreasing timestamps per lane — and exits nonzero
with the error list on failure.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import logio  # noqa: E402
from repro.obs.trace import chrome_trace, validate_chrome_trace  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("log", help="JSONL run log (written with --trace)")
    ap.add_argument("--out", default="",
                    help="write the Chrome trace JSON here "
                         "(default: <log>.trace.json)")
    ap.add_argument("--validate", action="store_true",
                    help="also structurally validate the export and "
                         "exit nonzero on any error (CI mode)")
    args = ap.parse_args()

    try:
        records = logio.read_records(args.log)
    except logio.ObsLogError as e:
        raise SystemExit(str(e))

    trace = chrome_trace(records)
    slices = sum(1 for e in trace["traceEvents"] if e["ph"] == "X")
    if slices == 0:
        raise SystemExit(
            f"{args.log}: no trace slices — was the run recorded with "
            f"tracing on (--trace / ObsConfig.trace)?")

    out = args.out or f"{args.log}.trace.json"
    Path(out).write_text(json.dumps(trace, sort_keys=True) + "\n")
    lanes = {(e["pid"], e["tid"]) for e in trace["traceEvents"]
             if e["ph"] != "M"}
    print(f"{out}: {slices} slices across {len(lanes)} lanes "
          f"({len(trace['traceEvents'])} events)")

    if args.validate:
        errors = validate_chrome_trace(trace)
        if errors:
            print(f"{out}: INVALID ({len(errors)} error(s))")
            for e in errors[:20]:
                print(f"  {e}")
            return 1
        print(f"{out}: structurally valid Chrome trace")
    return 0


if __name__ == "__main__":
    sys.exit(main())
