#!/usr/bin/env python
"""Docs-consistency check (CI: `make docs-check`).

Fails when README.md / docs/ / benchmarks/README.md reference things
that no longer exist, so the docs cannot silently drift from the code:

* file/path references (``docs/wire-format.md``, ``examples/*.py``) must
  exist on disk;
* ``repro.*`` dotted module references must resolve to a module file or
  package under src/ (trailing attribute components are allowed);
* ``--flags`` inside fenced command blocks that invoke
  ``repro.launch.train`` or ``benchmarks.run`` must appear verbatim in
  that entry point's source;
* ``--only <regime>`` values must name a registered benchmark regime
  (the ALL dict, ``kernel`` or ``all``) — both in fenced
  ``benchmarks.run`` commands AND in inline code spans across every
  doc file (so prose like "the ``--only engine`` run" can't outlive a
  renamed regime);
* ``CommConfig.field`` / ``FedConfig.field`` references must name real
  dataclass fields;
* ``make target`` references must name real Makefile targets;
* ``docs/configuration.md`` must be byte-identical to what
  ``tools/gen_config_docs.py`` generates from the config dataclasses
  (every field present, nothing stale);
* the metric catalogue in ``docs/observability.md`` must list exactly
  the metrics registered in ``src/repro/obs/schema.py`` (regex-parsed
  ``Metric("name", ...)`` literals — no package import), so the obs
  docs can't drift from the record schema;
* the record-type table in the same doc's "Record schema" section
  must list exactly the ``RECORDS`` registry's record types;
* the aggregator and attack tables in ``docs/robustness.md`` must list
  exactly the ``AGGREGATORS`` / ``ATTACKS`` registries of
  ``src/repro/configs/base.py`` (regex-parsed tuples — no package
  import), so the robustness doc can't drift from the fleet's
  registered combiners and fault injectors;
* the committed kernel tuning table ``src/repro/kernels/tuning.json``
  must parse and its entry keys must equal the ``KERNELS`` registry in
  ``src/repro/kernels/__init__.py`` (regex-parsed — no package
  import), so a kernel rename can't silently orphan its tuning entry
  (``make autotune-check`` additionally compiles each entry).

Pure stdlib + text matching — no imports of the package, so it runs in
seconds on a bare checkout.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = sorted(
    [ROOT / "README.md", ROOT / "benchmarks" / "README.md"]
    + list((ROOT / "docs").glob("*.md")))

CLI_SOURCES = {
    "repro.launch.train": ROOT / "src" / "repro" / "launch" / "train.py",
    "benchmarks.run": ROOT / "benchmarks" / "run.py",
}
CONFIG_SOURCE = ROOT / "src" / "repro" / "configs" / "base.py"
OBS_SCHEMA_SOURCE = ROOT / "src" / "repro" / "obs" / "schema.py"
KERNELS_SOURCE = ROOT / "src" / "repro" / "kernels" / "__init__.py"
TUNING_JSON = ROOT / "src" / "repro" / "kernels" / "tuning.json"
#: the KERNELS registry is a tuple of one string literal per line
KERNELS_RE = re.compile(r"^KERNELS = \((.*?)\)", re.S | re.M)
OBS_DOC = ROOT / "docs" / "observability.md"
#: the metric registry declares one Metric("name", ...) literal per
#: line (the schema docstring mandates it) — regex-parseable here
METRIC_DECL_RE = re.compile(r'\bMetric\(\s*"(\w+)"')
#: record types are declared as `"name": RecordType(` entries of the
#: RECORDS dict in the schema module
RECORD_DECL_RE = re.compile(r'"(\w+)": RecordType\(')

PATH_RE = re.compile(r"[\w./-]+/[\w.-]+\.(?:py|md|json|yml|ini)\b")
MODULE_RE = re.compile(r"\brepro(?:\.\w+)+")
FIELD_RE = re.compile(
    r"\b(CommConfig|FedConfig|ModelConfig|SchedConfig|RobustConfig"
    r"|ObsConfig)\.(\w+)")
MAKE_RE = re.compile(r"\bmake ([\w-]+)")
FLAG_RE = re.compile(r"(?<!-)--([\w-]+)")
ONLY_RE = re.compile(r"--only[= ](\w+)")
# benchmark regime registry: keys of benchmarks/run.py's ALL dict plus
# the regimes main() special-cases
REGIME_RE = re.compile(r"^ALL = \{(.*?)\}", re.S | re.M)
EXTRA_REGIMES = {"kernel", "all"}


def module_resolves(dotted: str) -> bool:
    """Longest prefix of the dotted path must be a module file/package
    (trailing components may be attributes like FedEngine.round)."""
    parts = dotted.split(".")
    for end in range(len(parts), 1, -1):
        base = ROOT / "src" / Path(*parts[:end])
        if base.with_suffix(".py").is_file() or base.is_dir():
            return True
    return False


def bench_regimes(src: str):
    """Valid ``--only`` values: keys of benchmarks/run.py's ALL dict
    plus the special-cased ``kernel``/``all``."""
    m = REGIME_RE.search(src)
    names = set(re.findall(r'"(\w+)":', m.group(1))) if m else set()
    return names | EXTRA_REGIMES


def fenced_commands(text: str):
    """Command lines inside ``` blocks, with backslash continuations
    joined."""
    for block in re.findall(r"```(?:\w*)\n(.*?)```", text, re.S):
        joined = block.replace("\\\n", " ")
        for line in joined.splitlines():
            line = line.strip()
            if line and not line.startswith("#"):
                yield line


def check_file(doc: Path, make_targets, errors):
    text = doc.read_text()
    rel = doc.relative_to(ROOT)

    for m in PATH_RE.finditer(text):
        p = m.group(0).lstrip("./")
        if not (ROOT / p).exists():
            errors.append(f"{rel}: references missing path `{m.group(0)}`")

    for m in MODULE_RE.finditer(text):
        if not module_resolves(m.group(0)):
            errors.append(f"{rel}: references missing module `{m.group(0)}`")

    cfg_src = CONFIG_SOURCE.read_text()
    for m in FIELD_RE.finditer(text):
        cls, field = m.groups()
        if not re.search(rf"\b{field}\b", cfg_src):
            errors.append(f"{rel}: `{cls}.{field}` is not a config field")

    # `make target` only counts inside code spans/blocks — prose like
    # "references make every payload distinct" is not a target
    code_text = "\n".join(re.findall(r"`([^`\n]+)`", text)
                          + list(fenced_commands(text)))
    for m in MAKE_RE.finditer(code_text):
        if m.group(1) not in make_targets:
            errors.append(f"{rel}: `make {m.group(1)}` is not a Makefile "
                          f"target")

    # `--only <regime>` anywhere in code spans/blocks (not just fenced
    # benchmarks.run commands) must name a registered regime
    bench_src = CLI_SOURCES["benchmarks.run"].read_text()
    for regime in ONLY_RE.findall(code_text):
        if regime not in bench_regimes(bench_src):
            errors.append(
                f"{rel}: `--only {regime}` is not a registered "
                f"benchmark regime")

    for cmd in fenced_commands(text):
        for entry, src_path in CLI_SOURCES.items():
            if entry in cmd:
                src = src_path.read_text()
                for flag in FLAG_RE.findall(cmd):
                    if f'"--{flag}"' not in src:
                        errors.append(
                            f"{rel}: flag `--{flag}` not defined in "
                            f"{src_path.relative_to(ROOT)}")
                if entry == "benchmarks.run":
                    for regime in ONLY_RE.findall(cmd):
                        if regime not in bench_regimes(src):
                            errors.append(
                                f"{rel}: `--only {regime}` is not a "
                                f"registered benchmark regime")


def check_config_reference(errors) -> None:
    """docs/configuration.md is GENERATED (tools/gen_config_docs.py):
    regenerate in memory and fail on any drift from the dataclasses —
    a new/renamed/retyped config field without a doc rebuild is a CI
    error, which is what keeps the reference complete."""
    sys.path.insert(0, str(ROOT / "tools"))
    try:
        import gen_config_docs
    finally:
        sys.path.pop(0)
    target = ROOT / "docs" / "configuration.md"
    if not target.exists():
        errors.append("docs/configuration.md is missing — run "
                      "`python tools/gen_config_docs.py`")
        return
    if target.read_text() != gen_config_docs.generate():
        errors.append(
            "docs/configuration.md is stale (config dataclasses "
            "changed) — regenerate with `python tools/gen_config_docs"
            ".py`")


def check_metric_catalogue(errors) -> None:
    """The '## Metric catalogue' table in docs/observability.md must
    list EXACTLY the metrics registered in repro.obs.schema — a metric
    added/renamed without a doc update (or a doc row outliving its
    metric) is a CI error."""
    registered = set(METRIC_DECL_RE.findall(OBS_SCHEMA_SOURCE.read_text()))
    if not registered:
        errors.append("tools/check_docs.py: found no Metric(...) "
                      "declarations in src/repro/obs/schema.py")
        return
    if not OBS_DOC.exists():
        errors.append("docs/observability.md is missing (the obs metric "
                      "catalogue lives there)")
        return
    text = OBS_DOC.read_text()
    m = re.search(r"## Metric catalogue\n(.*?)(?:\n## |\Z)", text, re.S)
    if not m:
        errors.append("docs/observability.md: no '## Metric catalogue' "
                      "section")
        return
    documented = set(re.findall(r"^\| `(\w+)` \|", m.group(1), re.M))
    for name in sorted(registered - documented):
        errors.append(f"docs/observability.md: metric `{name}` is "
                      f"registered in repro.obs.schema but missing from "
                      f"the catalogue")
    for name in sorted(documented - registered):
        errors.append(f"docs/observability.md: catalogue row `{name}` "
                      f"is not a registered metric")


def check_record_table(errors) -> None:
    """The record-type table in docs/observability.md's '## Record
    schema' section must list exactly the record types registered in
    repro.obs.schema.RECORDS — a new record type without a doc row
    (or a row outliving its type) is a CI error."""
    registered = set(RECORD_DECL_RE.findall(OBS_SCHEMA_SOURCE.read_text()))
    if not registered:
        errors.append("tools/check_docs.py: found no RecordType "
                      "declarations in src/repro/obs/schema.py")
        return
    if not OBS_DOC.exists():
        return                      # already reported by the catalogue
    text = OBS_DOC.read_text()
    m = re.search(r"## Record schema\n(.*?)(?:\n## |\Z)", text, re.S)
    if not m:
        errors.append("docs/observability.md: no '## Record schema' "
                      "section")
        return
    documented = set(re.findall(r"^\| `(\w+)` \|", m.group(1), re.M))
    for name in sorted(registered - documented):
        errors.append(f"docs/observability.md: record type `{name}` is "
                      f"registered in repro.obs.schema but missing from "
                      f"the record table")
    for name in sorted(documented - registered):
        errors.append(f"docs/observability.md: record table row "
                      f"`{name}` is not a registered record type")


ROBUST_DOC = ROOT / "docs" / "robustness.md"
#: the adversarial-fleet registries are one-line string tuples in
#: src/repro/configs/base.py — regex-parseable without importing
ROBUST_REGISTRY_RE = {
    "Aggregators": re.compile(r"^AGGREGATORS = \((.*?)\)", re.S | re.M),
    "Attacks": re.compile(r"^ATTACKS = \((.*?)\)", re.S | re.M),
}


def check_robust_registries(errors) -> None:
    """The '## Aggregators' and '## Attacks' tables in
    docs/robustness.md must list EXACTLY the AGGREGATORS / ATTACKS
    registries of repro.configs.base — a combiner or fault injector
    added/renamed without a doc row (or a row outliving its registry
    entry) is a CI error."""
    src = CONFIG_SOURCE.read_text()
    if not ROBUST_DOC.exists():
        errors.append("docs/robustness.md is missing (the adversarial-"
                      "fleet registry tables live there)")
        return
    text = ROBUST_DOC.read_text()
    for section, regex in ROBUST_REGISTRY_RE.items():
        m = regex.search(src)
        registered = set(re.findall(r'"(\w+)"', m.group(1))) if m else set()
        if not registered:
            errors.append(f"tools/check_docs.py: found no "
                          f"{section.upper()} registry in "
                          f"src/repro/configs/base.py")
            continue
        sec = re.search(rf"## {section}\n(.*?)(?:\n## |\Z)", text, re.S)
        if not sec:
            errors.append(f"docs/robustness.md: no '## {section}' "
                          f"section")
            continue
        documented = set(re.findall(r"^\| `(\w+)` \|", sec.group(1),
                                    re.M))
        for name in sorted(registered - documented):
            errors.append(f"docs/robustness.md: `{name}` is registered "
                          f"in repro.configs.base but missing from the "
                          f"{section} table")
        for name in sorted(documented - registered):
            errors.append(f"docs/robustness.md: {section} table row "
                          f"`{name}` is not a registered name")


#: tuning keys are `<kernel>[@<dtype>][@n<chunk>]`
#: (`repro.kernels.tuning` — most specific first at lookup)
TUNING_KEY_RE = re.compile(
    r"^(?P<base>\w+?)(?:@(?P<dtype>[a-z][a-z0-9_]*))?(?:@n(?P<n>\d+))?$")
#: the storage dtypes a suffixed tuning key may name (mirrors
#: tools/autotune_kernels.py DTYPES — no package import here)
TUNING_DTYPES = {"float32", "bfloat16", "float8_e4m3fn", "float8_e5m2"}


def check_tuning_table(errors) -> None:
    """The committed kernel tuning table (src/repro/kernels/
    tuning.json) must parse and every entry key must be
    ``<kernel>[@<dtype>][@n<chunk>]`` with ``<kernel>`` in the KERNELS
    registry (regex-parsed from the kernels package __init__) and
    ``<dtype>`` a known storage format; every registered kernel must
    keep its bare fallback key — a renamed kernel whose tuning entry
    survives, or a kernel missing from the table, is a CI error.
    Compile-level validation lives in `make autotune-check`; this is
    the no-import text check."""
    import json
    m = KERNELS_RE.search(KERNELS_SOURCE.read_text())
    registered = set(re.findall(r'"(\w+)"', m.group(1))) if m else set()
    if not registered:
        errors.append("tools/check_docs.py: found no KERNELS registry "
                      "in src/repro/kernels/__init__.py")
        return
    if not TUNING_JSON.exists():
        errors.append("src/repro/kernels/tuning.json is missing — run "
                      "`make autotune`")
        return
    try:
        data = json.loads(TUNING_JSON.read_text())
    except ValueError as e:
        errors.append(f"src/repro/kernels/tuning.json: bad JSON ({e})")
        return
    entries = data.get("entries")
    if not isinstance(entries, dict):
        errors.append("src/repro/kernels/tuning.json: no 'entries' dict")
        return
    for name in sorted(registered - set(entries)):
        errors.append(f"src/repro/kernels/tuning.json: kernel `{name}` "
                      f"has no bare tuning entry — run `make autotune`")
    for key in sorted(entries):
        km = TUNING_KEY_RE.match(key)
        if not km or km.group("base") not in registered:
            errors.append(
                f"src/repro/kernels/tuning.json: entry `{key}` is not "
                f"in the repro.kernels.KERNELS registry (keys are "
                f"<kernel>[@<dtype>][@n<chunk>])")
        elif km.group("dtype") and km.group("dtype") not in TUNING_DTYPES:
            errors.append(
                f"src/repro/kernels/tuning.json: entry `{key}` names "
                f"unknown dtype `{km.group('dtype')}` (want one of "
                f"{sorted(TUNING_DTYPES)})")


def main() -> int:
    make_targets = set(re.findall(r"^([\w-]+):", (ROOT / "Makefile")
                                  .read_text(), re.M))
    errors: list = []
    for doc in DOC_FILES:
        if doc.exists():
            check_file(doc, make_targets, errors)
    check_config_reference(errors)
    check_metric_catalogue(errors)
    check_record_table(errors)
    check_robust_registries(errors)
    check_tuning_table(errors)
    if errors:
        print(f"docs-check: {len(errors)} stale reference(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"docs-check: {len(DOC_FILES)} files clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
