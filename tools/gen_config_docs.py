#!/usr/bin/env python
"""Generate docs/configuration.md from the config dataclasses.

Parses ``src/repro/configs/base.py`` with the stdlib ``ast`` module (no
package import, mirroring tools/check_docs.py) and emits one reference
table per runtime config class — `FedConfig`, `CommConfig`,
`SchedConfig`, `ObsConfig` — with every field's name, type, default, the
``repro.launch.train`` flag that sets it (where one exists), and the
description recovered from the source comments around the field.

The output is DETERMINISTIC: same source in, same bytes out.
`tools/check_docs.py` regenerates it in memory on every ``make
docs-check`` and fails CI when the committed ``docs/configuration.md``
drifts from the dataclasses — add a field and CI will tell you to run

    python tools/gen_config_docs.py
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CONFIG_SOURCE = ROOT / "src" / "repro" / "configs" / "base.py"
TRAIN_SOURCE = ROOT / "src" / "repro" / "launch" / "train.py"
OUT = ROOT / "docs" / "configuration.md"

#: the runtime config classes the reference covers, in document order
CLASSES = ("FedConfig", "CommConfig", "SchedConfig", "RobustConfig",
           "ObsConfig")

#: fields whose train.py flag does NOT follow the name == flag rule
FLAG_OVERRIDES = {
    ("FedConfig", "num_clients"): "clients",
    ("FedConfig", "total_rounds"): "rounds",
    ("CommConfig", "use_pallas"): "comm-pallas",
    ("SchedConfig", "discipline"): "schedule",
    ("ObsConfig", "flush_every"): "obs-flush-every",
}
#: fields that must NOT auto-match a same-named train.py flag (the
#: flag exists but means something else)
FLAG_DENY = {
    ("CommConfig", "seed"),      # --seed is the launcher's global RNG
    ("SchedConfig", "seed"),
    ("FedConfig", "seed"),
    ("FedConfig", "schedule"),   # --schedule is SchedConfig.discipline
    ("RobustConfig", "seed"),    # masks reuse the launcher's --seed
}

HEADER = """\
<!-- GENERATED FILE — do not edit by hand.
     Source of truth: src/repro/configs/base.py (+ the flag registry in
     src/repro/launch/train.py).  Regenerate with:
         python tools/gen_config_docs.py
     `make docs-check` (tools/check_docs.py) fails CI when this file
     drifts from the dataclasses. -->

# Configuration reference

Every field of the federated runtime's config dataclasses
(`repro.configs.base`).  `FedConfig` owns the round (Alg. 1
hyper-parameters) and embeds one `CommConfig` (the client<->server
wire model), one `SchedConfig` (virtual-time round scheduling), one
`RobustConfig` (the adversarial fleet — docs/robustness.md) and
one `ObsConfig` (structured telemetry — docs/observability.md).
Model-architecture configs (`ModelConfig` and the zoo under
`src/repro/configs/`) are intentionally out of scope: they describe
networks, not the runtime.

Flags column: the `repro.launch.train` CLI flag that sets the field,
where one exists (the launcher composes the configs; library users
construct them directly).
"""


def _class_nodes(tree: ast.Module):
    return {n.name: n for n in tree.body if isinstance(n, ast.ClassDef)}


def _comment_text(line: str) -> str:
    """The comment payload of a source line ('' when none)."""
    m = re.search(r"#[:]?\s?(.*)$", line)
    return m.group(1).rstrip() if m else ""


def _is_separator(text: str) -> bool:
    return bool(re.match(r"^\s*-{4,}", text)) or bool(
        re.match(r"^={4,}", text))


def _strip_separators(text: str) -> str:
    """Drop '---- section ----' decoration, keep any inner words."""
    return re.sub(r"-{4,}", "", text).strip()


def _is_continuation_line(line: str) -> bool:
    """Whether a full-line comment continues the PREVIOUS field's
    inline comment (deep `#` column, or deep indentation inside the
    comment) rather than introducing the next field."""
    return line.index("#") > 8 or bool(re.match(r"\s*#\s{3,}", line))


def _field_description(lines, node: ast.AnnAssign, next_lineno: int) -> str:
    """Recover a field's doc from the comments around it: the
    contiguous full-line comment block directly above, the inline
    comment on the assignment line(s), and continuation comment lines
    below (before the next field)."""
    parts = []
    # comment block immediately above (no blank line in between);
    # deep-indented lines there continue the previous field, not this
    above = []
    i = node.lineno - 2              # 0-based line above the field
    while i >= 0 and lines[i].strip().startswith("#"):
        if not _is_continuation_line(lines[i]):
            above.append(_comment_text(lines[i]))
        i -= 1
    for t in reversed(above):
        if _is_separator(t):
            continue
        parts.append(t)
    # inline comment(s) on the assignment's own line span
    for ln in range(node.lineno - 1, node.end_lineno):
        code = lines[ln]
        if "#" in code:
            t = _comment_text(code)
            if t and not _is_separator(t):
                parts.append(t)
    # continuation comments below: only DEEP-indented ones (aligned
    # with the inline-comment column) — a comment block at the field
    # indentation introduces the NEXT field, not this one
    ln = node.end_lineno
    while ln < min(next_lineno - 1, len(lines)):
        stripped = lines[ln].strip()
        if not (stripped.startswith("#")
                and _is_continuation_line(lines[ln])):
            break
        t = _comment_text(lines[ln])
        if t and not _is_separator(t):
            parts.append(t)
        ln += 1
    text = " ".join(p.strip() for p in parts if p.strip())
    return re.sub(r"\s+", " ", text).strip()


def _fields(cls: ast.ClassDef, lines):
    """(name, type, default, description) per dataclass field."""
    anns = [n for n in cls.body if isinstance(n, ast.AnnAssign)
            and isinstance(n.target, ast.Name)]
    out = []
    for i, node in enumerate(anns):
        nxt = anns[i + 1].lineno if i + 1 < len(anns) else (
            cls.end_lineno + 1)
        default = ""
        if node.value is not None:
            default = ast.unparse(node.value)
            # field(default_factory=X) reads better as its result
            m = re.match(r"field\(default_factory=(\w+)\)", default)
            if m:
                default = f"{m.group(1)}()"
        out.append((node.target.id, ast.unparse(node.annotation),
                    default, _field_description(lines, node, nxt)))
    return out


def _train_flags(train_src: str):
    """Flags actually registered by repro.launch.train."""
    return set(re.findall(r'add_argument\(\s*"--([\w-]+)"', train_src))


def _flag_for(cls: str, name: str, flags) -> str:
    if (cls, name) in FLAG_DENY:
        return ""
    over = FLAG_OVERRIDES.get((cls, name))
    if over:
        return f"--{over}" if over in flags else ""
    auto = name.replace("_", "-")
    return f"--{auto}" if auto in flags else ""


def _md_escape(text: str) -> str:
    return text.replace("|", "\\|")


def _class_doc(cls: ast.ClassDef) -> str:
    doc = ast.get_docstring(cls) or ""
    return doc.split("\n\n")[0].replace("\n", " ").strip()


def generate() -> str:
    src = CONFIG_SOURCE.read_text()
    lines = src.splitlines()
    tree = ast.parse(src)
    nodes = _class_nodes(tree)
    flags = _train_flags(TRAIN_SOURCE.read_text())
    chunks = [HEADER]
    for cls_name in CLASSES:
        cls = nodes[cls_name]
        chunks.append(f"\n## `{cls_name}`\n")
        summary = _class_doc(cls)
        if summary:
            chunks.append(f"\n{summary}\n")
        chunks.append(
            "\n| field | type | default | train.py flag | description |"
            "\n| --- | --- | --- | --- | --- |")
        for name, ann, default, desc in _fields(cls, lines):
            flag = _flag_for(cls_name, name, flags)
            chunks.append(
                f"\n| `{name}` | `{_md_escape(ann)}` "
                f"| `{_md_escape(default)}` "
                f"| {f'`{flag}`' if flag else '—'} "
                f"| {_md_escape(desc) or '—'} |")
        chunks.append("\n")
    return "".join(chunks)


def main(argv) -> int:
    text = generate()
    if "--check" in argv:
        if not OUT.exists() or OUT.read_text() != text:
            print(f"{OUT.relative_to(ROOT)} is stale — regenerate with "
                  f"`python tools/gen_config_docs.py`")
            return 1
        print(f"{OUT.relative_to(ROOT)} is up to date")
        return 0
    OUT.write_text(text)
    print(f"wrote {OUT.relative_to(ROOT)}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
