#!/usr/bin/env python
"""Block-size autotuner for the Pallas kernels.

Sweep mode (default)::

    PYTHONPATH=src python tools/autotune_kernels.py

times every kernel in `repro.kernels.KERNELS` at the committed
benchmark sizes (the `benchmarks.run` engine workload: a 4-client
cohort over the packed (54, 1024) wire buffer) across a small grid of
candidate (block_n, block_r, block_c) launch geometries, and writes
the per-kernel winners to ``src/repro/kernels/tuning.json`` — the
table `repro.kernels.tuning` consults at trace time.  Block shape
never changes kernel values (every entry point is elementwise per
coordinate), only launch geometry, so re-tuning is always safe.

Check mode (CI: `make autotune-check`)::

    PYTHONPATH=src python tools/autotune_kernels.py --check

validates the COMMITTED table: it must parse, carry ``version: 1``,
its keys must equal the `repro.kernels.KERNELS` registry exactly, and
every entry's block fields must be ints >= 1.  Then every kernel is
compiled and run on CPU (interpret mode) at a deliberately ragged
size with its committed blocks, and the result asserted bitwise equal
to the safe-default geometry — a committed entry that fails to
compile, or that somehow changed values, is a CI error.  Exits
nonzero on any failure.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import INTERPRET, KERNELS, tuning
from repro.kernels.quantize import (broadcast_roundtrip_batched,
                                    quant_roundtrip_batched,
                                    sign_roundtrip_batched,
                                    topk_threshold_batched,
                                    uplink_roundtrip_batched)
from repro.kernels.sophia_update import sophia_update_batched
from repro.kernels.stale_accum import stale_accum_flat

#: the engine benchmark workload (benchmarks/run.py `fig_engine`):
#: 4 clients, MLP packed to a (54, 1024) wire buffer
SWEEP_N, SWEEP_R, SWEEP_C = 4, 54, 1024
#: ragged check size: nothing divides the committed blocks evenly
CHECK_N, CHECK_R, CHECK_C = 3, 20, 100

QMAX = 127


def _flatten(tree):
    return jax.tree.leaves(tree)


def make_runners(N: int, R: int, C: int):
    """kernel name -> fn(blocks3) running that kernel's client-batched
    launch on fixed deterministic inputs, returning the output leaves
    (blocked until ready).  ``blocks3`` is the (bn, br, bc) override
    handed to the kernel; None runs the tuned/default path."""
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    x = jax.random.normal(ks[0], (N, R, C), jnp.float32)
    y = jax.random.normal(ks[1], (N, R, C), jnp.float32)
    z = jax.random.normal(ks[2], (N, R, C), jnp.float32)
    g = jax.random.normal(ks[3], (N, R, C), jnp.float32)
    noise = jax.random.uniform(ks[4], (N, R, C), jnp.float32)
    scale = 0.1 + jax.random.uniform(ks[5], (N, R, 1), jnp.float32)
    theta2 = jax.random.normal(ks[6], (R, C), jnp.float32)
    wires = jax.random.normal(ks[7], (N, R, C), jnp.float32)
    weights = jnp.linspace(0.5, 1.0, N)
    cscale = jnp.linspace(0.9, 1.1, N)

    def run(fn, *args, **kw):
        out = fn(*args, **kw)
        leaves = _flatten(out)
        jax.block_until_ready(leaves)
        return leaves

    return {
        "quant_roundtrip": lambda b: run(
            quant_roundtrip_batched, x, noise, scale, qmax=QMAX,
            interpret=INTERPRET, blocks=b),
        "broadcast_roundtrip": lambda b: run(
            broadcast_roundtrip_batched, theta2, y, z, noise, scale,
            qmax=QMAX, interpret=INTERPRET, blocks=b),
        "uplink_roundtrip": lambda b: run(
            uplink_roundtrip_batched, x, theta2, z, noise, scale,
            qmax=QMAX, interpret=INTERPRET, blocks=b),
        "sign_roundtrip": lambda b: run(
            sign_roundtrip_batched, x, cscale, interpret=INTERPRET,
            blocks=b),
        "topk_threshold": lambda b: run(
            topk_threshold_batched, x, cscale, interpret=INTERPRET,
            blocks=b),
        "sophia_update": lambda b: run(
            sophia_update_batched, x, y, z, g, noise, True, 0.01,
            beta1=0.9, beta2=0.99, rho=0.05, eps=1e-12,
            weight_decay=0.0, interpret=INTERPRET, blocks=b),
        # the tuned stale_accum path pins block_k=1 (bitwise add
        # order); the sweep/check only exercise (1, br, bc)
        "stale_accum": lambda b: run(
            stale_accum_flat, wires, weights, jnp.float32(1.0),
            interpret=INTERPRET,
            blocks=None if b is None else (1, b[1], b[2])),
    }


def candidates(N: int):
    """The sweep grid: client-axis batching is the interpret-mode
    lever (fewer grid steps), tile shape matters on real hardware."""
    bns = sorted({1, 2, N})
    tiles = [(tuning.DEFAULT_BLOCK_R, tuning.DEFAULT_BLOCK_C), (64, 256)]
    return [(bn, br, bc) for bn in bns for (br, bc) in tiles]


def time_blocks(runner, blocks, repeats: int) -> float:
    runner(blocks)                      # compile + warm up
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        runner(blocks)
        best = min(best, time.perf_counter() - t0)
    return best


def sweep(out_path: str, repeats: int) -> int:
    runners = make_runners(SWEEP_N, SWEEP_R, SWEEP_C)
    entries = {}
    for kernel in KERNELS:
        runner = runners[kernel]
        results = []
        for blocks in candidates(SWEEP_N):
            us = time_blocks(runner, blocks, repeats) * 1e6
            results.append((us, blocks))
            print(f"  {kernel:>20s}  bn={blocks[0]:<2d} "
                  f"br={blocks[1]:<4d} bc={blocks[2]:<4d} "
                  f"{us:10.1f} us")
        best_us, (bn, br, bc) = min(results)
        if kernel == "stale_accum":
            bn = 1                      # tuned path never blocks K
        entries[kernel] = {"block_n": bn, "block_r": br, "block_c": bc}
        print(f"  {kernel:>20s}  -> bn={bn} br={br} bc={bc} "
              f"({best_us:.1f} us)\n")
    table = {"version": 1,
             "backend": ("cpu-interpret" if INTERPRET
                         else jax.default_backend()),
             "entries": {k: entries[k] for k in sorted(entries)}}
    with open(out_path, "w") as f:
        json.dump(table, f, indent=2, sort_keys=False)
        f.write("\n")
    print(f"wrote {out_path}")
    return 0


def check(path: str) -> int:
    errors = []
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:
        print(f"autotune-check: cannot read {path}: {e}")
        return 1
    if data.get("version") != 1:
        errors.append(f"version is {data.get('version')!r}, want 1")
    entries = data.get("entries")
    if not isinstance(entries, dict):
        print(f"autotune-check: {path} has no 'entries' dict")
        return 1
    got, want = set(entries), set(KERNELS)
    for k in sorted(want - got):
        errors.append(f"kernel `{k}` has no tuning entry")
    for k in sorted(got - want):
        errors.append(f"entry `{k}` is not a registered kernel")
    for k, e in sorted(entries.items()):
        for field in ("block_n", "block_r", "block_c"):
            v = e.get(field) if isinstance(e, dict) else None
            if not isinstance(v, int) or v < 1:
                errors.append(f"{k}.{field} = {v!r} (want int >= 1)")
    if errors:
        print(f"autotune-check: {len(errors)} problem(s) in {path}")
        for e in errors:
            print(f"  {e}")
        return 1

    # compile + run every kernel at a ragged size with the committed
    # blocks, and pin bitwise equality vs the safe-default geometry
    runners = make_runners(CHECK_N, CHECK_R, CHECK_C)
    default = (tuning.DEFAULT_BLOCK_N, tuning.DEFAULT_BLOCK_R,
               tuning.DEFAULT_BLOCK_C)
    for kernel in KERNELS:
        e = entries[kernel]
        blocks = (e["block_n"], e["block_r"], e["block_c"])
        try:
            tuned = runners[kernel](blocks)
            base = runners[kernel](default)
        except Exception as exc:   # noqa: BLE001 - report, don't crash
            errors.append(f"{kernel}: blocks={blocks} failed to "
                          f"compile/run: {exc}")
            continue
        for t, b in zip(tuned, base):
            if not np.array_equal(np.asarray(t), np.asarray(b)):
                errors.append(f"{kernel}: blocks={blocks} changed "
                              f"values vs default geometry")
                break
        print(f"  {kernel:>20s}  blocks={blocks} ok")
    if errors:
        print(f"autotune-check: {len(errors)} kernel failure(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"autotune-check: {path} ok ({len(KERNELS)} kernels)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="validate the committed tuning.json instead "
                         "of sweeping")
    ap.add_argument("--out", default=tuning.TUNING_PATH,
                    help="tuning table path (default: the committed "
                         "src/repro/kernels/tuning.json)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats per candidate (sweep mode)")
    args = ap.parse_args()
    if args.check:
        return check(args.out)
    return sweep(args.out, args.repeats)


if __name__ == "__main__":
    sys.exit(main())
