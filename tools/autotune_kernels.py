#!/usr/bin/env python
"""Block-size autotuner for the Pallas kernels.

Sweep mode (default)::

    PYTHONPATH=src python tools/autotune_kernels.py
    PYTHONPATH=src python tools/autotune_kernels.py --dtype bfloat16

times every kernel in `repro.kernels.KERNELS` at the committed
benchmark sizes (the `benchmarks.run` engine workload: a 4-client
cohort over the packed (54, 1024) wire buffer) across a small grid of
candidate (block_n, block_r, block_c) launch geometries, and merges
the per-kernel winners into ``src/repro/kernels/tuning.json`` — the
table `repro.kernels.tuning` consults at trace time.  Without
``--dtype`` the sweep runs fp32 inputs and writes the bare
``<kernel>`` keys; with ``--dtype`` the resident-state inputs
(theta/m/h/wires) are cast to that storage dtype and the winners land
under ``<kernel>@<dtype>`` keys — the narrow-dtype geometries the
lookup in `repro.kernels.tuning` prefers (most specific first:
``<kernel>@<dtype>@n<chunk>``, then ``<kernel>@<dtype>``, then
``<kernel>``).  Sweeps MERGE: re-tuning one dtype never drops the
others' keys.  Block shape never changes kernel values (every entry
point is elementwise per coordinate), only launch geometry, so
re-tuning is always safe.

Check mode (CI: `make autotune-check`)::

    PYTHONPATH=src python tools/autotune_kernels.py --check

validates the COMMITTED table: it must parse, carry ``version: 1``,
every key must be ``<kernel>[@<dtype>][@n<chunk>]`` with ``<kernel>``
in the `repro.kernels.KERNELS` registry (every registered kernel must
own a bare fallback key; ``<dtype>`` must be a known storage dtype),
and every entry's block fields must be ints >= 1.  Then every entry
is compiled and run on CPU (interpret mode) at a deliberately ragged
size with its committed blocks — at the entry's own dtype — and the
result asserted bitwise equal to the safe-default geometry at that
dtype: a committed entry that fails to compile, or that somehow
changed values, is a CI error.  Exits nonzero on any failure.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import INTERPRET, KERNELS, tuning
from repro.kernels.quantize import (broadcast_roundtrip_batched,
                                    quant_roundtrip_batched,
                                    sign_roundtrip_batched,
                                    topk_threshold_batched,
                                    uplink_roundtrip_batched)
from repro.kernels.robust_agg import robust_agg_flat
from repro.kernels.sophia_update import sophia_update_batched
from repro.kernels.stale_accum import stale_accum_flat

#: the engine benchmark workload (benchmarks/run.py `fig_engine`):
#: 4 clients, MLP packed to a (54, 1024) wire buffer
SWEEP_N, SWEEP_R, SWEEP_C = 4, 54, 1024
#: ragged check size: nothing divides the committed blocks evenly
CHECK_N, CHECK_R, CHECK_C = 3, 20, 100

QMAX = 127

#: the storage dtypes a `--dtype` sweep (or a suffixed tuning key) may
#: name — the resident-state formats of `repro.comm.flat`
DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
          "float8_e4m3fn": jnp.float8_e4m3fn,
          "float8_e5m2": jnp.float8_e5m2}
#: tuning keys are `<kernel>[@<dtype>][@n<chunk>]`
KEY_RE = re.compile(
    r"^(?P<base>\w+?)(?:@(?P<dtype>[a-z][a-z0-9_]*))?(?:@n(?P<n>\d+))?$")


def _flatten(tree):
    return jax.tree.leaves(tree)


def make_runners(N: int, R: int, C: int, dtype=None):
    """kernel name -> fn(blocks3) running that kernel's client-batched
    launch on fixed deterministic inputs, returning the output leaves
    (blocked until ready).  ``blocks3`` is the (bn, br, bc) override
    handed to the kernel; None runs the tuned/default path.  ``dtype``
    casts the resident-state inputs (theta/m/h/replica/EF/wire
    streams) to that storage format — the kernels upcast loads
    in-VMEM, exactly the narrow-resident engine path; gradient/noise/
    scale inputs stay fp32 as in the engine."""
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    st = dtype or jnp.float32
    x = jax.random.normal(ks[0], (N, R, C), jnp.float32).astype(st)
    y = jax.random.normal(ks[1], (N, R, C), jnp.float32).astype(st)
    z = jax.random.normal(ks[2], (N, R, C), jnp.float32).astype(st)
    g = jax.random.normal(ks[3], (N, R, C), jnp.float32)
    noise = jax.random.uniform(ks[4], (N, R, C), jnp.float32)
    scale = 0.1 + jax.random.uniform(ks[5], (N, R, 1), jnp.float32)
    theta2 = jax.random.normal(ks[6], (R, C), jnp.float32).astype(st)
    wires = jax.random.normal(ks[7], (N, R, C), jnp.float32).astype(st)
    weights = jnp.linspace(0.5, 1.0, N)
    cscale = jnp.linspace(0.9, 1.1, N)

    def run(fn, *args, **kw):
        out = fn(*args, **kw)
        leaves = _flatten(out)
        jax.block_until_ready(leaves)
        return leaves

    return {
        "quant_roundtrip": lambda b: run(
            quant_roundtrip_batched, x, noise, scale, qmax=QMAX,
            interpret=INTERPRET, blocks=b),
        "broadcast_roundtrip": lambda b: run(
            broadcast_roundtrip_batched, theta2, y, z, noise, scale,
            qmax=QMAX, interpret=INTERPRET, blocks=b),
        "uplink_roundtrip": lambda b: run(
            uplink_roundtrip_batched, x, theta2, z, noise, scale,
            qmax=QMAX, interpret=INTERPRET, blocks=b),
        "sign_roundtrip": lambda b: run(
            sign_roundtrip_batched, x, cscale, interpret=INTERPRET,
            blocks=b),
        "topk_threshold": lambda b: run(
            topk_threshold_batched, x, cscale, interpret=INTERPRET,
            blocks=b),
        "sophia_update": lambda b: run(
            sophia_update_batched, x, y, z, g, noise, True, 0.01,
            beta1=0.9, beta2=0.99, rho=0.05, eps=1e-12,
            weight_decay=0.0, interpret=INTERPRET, blocks=b),
        # the tuned stale_accum path pins block_k=1 (bitwise add
        # order); the sweep/check only exercise (1, br, bc)
        "stale_accum": lambda b: run(
            stale_accum_flat, wires, weights, jnp.float32(1.0),
            interpret=INTERPRET,
            blocks=None if b is None else (1, b[1], b[2])),
        # robust_agg holds the whole K axis in-block (trimming needs
        # every wire at once), so only the (br, bc) tile is tunable
        "robust_agg": lambda b: run(
            robust_agg_flat, wires, weights, cscale, trim=1,
            normalize=True, interpret=INTERPRET,
            blocks=None if b is None else (b[1], b[2])),
    }


def candidates(N: int):
    """The sweep grid: client-axis batching is the interpret-mode
    lever (fewer grid steps), tile shape matters on real hardware."""
    bns = sorted({1, 2, N})
    tiles = [(tuning.DEFAULT_BLOCK_R, tuning.DEFAULT_BLOCK_C), (64, 256)]
    return [(bn, br, bc) for bn in bns for (br, bc) in tiles]


def time_blocks(runner, blocks, repeats: int) -> float:
    runner(blocks)                      # compile + warm up
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        runner(blocks)
        best = min(best, time.perf_counter() - t0)
    return best


def sweep(out_path: str, repeats: int, dtype_name: str = "") -> int:
    dt = DTYPES[dtype_name] if dtype_name else None
    suffix = f"@{dtype_name}" if dtype_name else ""
    runners = make_runners(SWEEP_N, SWEEP_R, SWEEP_C, dtype=dt)
    entries = {}
    for kernel in KERNELS:
        runner = runners[kernel]
        results = []
        for blocks in candidates(SWEEP_N):
            us = time_blocks(runner, blocks, repeats) * 1e6
            results.append((us, blocks))
            print(f"  {kernel + suffix:>32s}  bn={blocks[0]:<2d} "
                  f"br={blocks[1]:<4d} bc={blocks[2]:<4d} "
                  f"{us:10.1f} us")
        best_us, (bn, br, bc) = min(results)
        if kernel in ("stale_accum", "robust_agg"):
            bn = 1                      # tuned path never blocks K
        entries[kernel + suffix] = {"block_n": bn, "block_r": br,
                                    "block_c": bc}
        print(f"  {kernel + suffix:>32s}  -> bn={bn} br={br} bc={bc} "
              f"({best_us:.1f} us)\n")
    # merge into the committed table: a sweep only owns the keys it
    # timed (one dtype's worth), the other dtypes' entries survive
    existing = {}
    try:
        with open(out_path) as f:
            existing = json.load(f).get("entries", {})
    except (OSError, ValueError):
        pass
    existing.update(entries)
    table = {"version": 1,
             "backend": ("cpu-interpret" if INTERPRET
                         else jax.default_backend()),
             "entries": {k: existing[k] for k in sorted(existing)}}
    with open(out_path, "w") as f:
        json.dump(table, f, indent=2, sort_keys=False)
        f.write("\n")
    print(f"wrote {out_path}")
    return 0


def check(path: str) -> int:
    errors = []
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:
        print(f"autotune-check: cannot read {path}: {e}")
        return 1
    if data.get("version") != 1:
        errors.append(f"version is {data.get('version')!r}, want 1")
    entries = data.get("entries")
    if not isinstance(entries, dict):
        print(f"autotune-check: {path} has no 'entries' dict")
        return 1
    # every key must parse as <kernel>[@<dtype>][@n<chunk>]; every
    # registered kernel must keep its bare fallback entry
    parsed = {}
    for k in sorted(entries):
        m = KEY_RE.match(k)
        if not m or m.group("base") not in KERNELS:
            errors.append(f"entry `{k}` does not name a registered "
                          f"kernel (format: <kernel>[@<dtype>]"
                          f"[@n<chunk>])")
            continue
        if m.group("dtype") and m.group("dtype") not in DTYPES:
            errors.append(f"entry `{k}`: unknown dtype "
                          f"`{m.group('dtype')}` (want one of "
                          f"{sorted(DTYPES)})")
            continue
        parsed[k] = m
    for k in sorted(set(KERNELS) - set(entries)):
        errors.append(f"kernel `{k}` has no bare tuning entry")
    for k, e in sorted(entries.items()):
        for field in ("block_n", "block_r", "block_c"):
            v = e.get(field) if isinstance(e, dict) else None
            if not isinstance(v, int) or v < 1:
                errors.append(f"{k}.{field} = {v!r} (want int >= 1)")
    if errors:
        print(f"autotune-check: {len(errors)} problem(s) in {path}")
        for e in errors:
            print(f"  {e}")
        return 1

    # compile + run every entry at a ragged size with the committed
    # blocks — at the entry's own dtype — and pin bitwise equality vs
    # the safe-default geometry at that dtype
    runners_at = {None: make_runners(CHECK_N, CHECK_R, CHECK_C)}
    default = (tuning.DEFAULT_BLOCK_N, tuning.DEFAULT_BLOCK_R,
               tuning.DEFAULT_BLOCK_C)
    for key, m in sorted(parsed.items()):
        kernel, dname = m.group("base"), m.group("dtype")
        if dname not in runners_at:
            runners_at[dname] = make_runners(
                CHECK_N, CHECK_R, CHECK_C, dtype=DTYPES[dname])
        runners = runners_at[dname]
        e = entries[key]
        blocks = (e["block_n"], e["block_r"], e["block_c"])
        try:
            tuned = runners[kernel](blocks)
            base = runners[kernel](default)
        except Exception as exc:   # noqa: BLE001 - report, don't crash
            errors.append(f"{key}: blocks={blocks} failed to "
                          f"compile/run: {exc}")
            continue
        for t, b in zip(tuned, base):
            if not np.array_equal(np.asarray(t), np.asarray(b)):
                errors.append(f"{key}: blocks={blocks} changed "
                              f"values vs default geometry")
                break
        print(f"  {key:>32s}  blocks={blocks} ok")
    if errors:
        print(f"autotune-check: {len(errors)} kernel failure(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"autotune-check: {path} ok ({len(parsed)} entries)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="validate the committed tuning.json instead "
                         "of sweeping")
    ap.add_argument("--out", default=tuning.TUNING_PATH,
                    help="tuning table path (default: the committed "
                         "src/repro/kernels/tuning.json)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats per candidate (sweep mode)")
    ap.add_argument("--dtype", default="", choices=[""] + sorted(DTYPES),
                    help="sweep with resident-state inputs in this "
                         "storage dtype and record the winners under "
                         "<kernel>@<dtype> keys (sweep mode)")
    args = ap.parse_args()
    if args.check:
        return check(args.out)
    return sweep(args.out, args.repeats, args.dtype)


if __name__ == "__main__":
    sys.exit(main())
