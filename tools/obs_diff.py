#!/usr/bin/env python
"""Cross-run regression diff over two obs logs (the run observatory).

Compares two runs record-by-record: the manifests first (schema
version, registry fingerprint, run meta), then every aligned record
pair per metric, with per-metric drift bands:

    python tools/obs_diff.py runs/a.jsonl runs/b.jsonl
    python tools/obs_diff.py a.jsonl b.jsonl --rtol 1e-4 \
        --band loss=1e-2 --band eval_loss=1e-2

Alignment keys: ``round`` records by round index, ``sched_event`` by
version, ``sched_dispatch`` by trace id, ``bench`` rows by name;
everything else by position.  Integer metrics (the exact byte
counters) must match EXACTLY regardless of bands — a byte drift is a
wire-accounting change, never noise.  Float metrics pass within
``--band <metric>=<rtol>`` (falling back to ``--rtol``, default 0).

Exit status: 0 when every compared metric is within its band and the
record counts line up ("zero drift" when everything matched exactly —
the `make obs-trace-smoke` self-compare gate), 1 otherwise.  Degenerate
logs fail with a one-line diagnosis (repro.obs.logio), never a
traceback.
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import logio, schema  # noqa: E402

#: alignment key per record type (fallback: position in the log)
ALIGN_KEYS = {"round": "round", "sched_event": "version",
              "sched_dispatch": "trace_id", "bench": "name"}
#: fields that identify a record rather than measure it, plus host
#: wall-clock timings (machine noise, not regression signal — the
#: virtual clock and byte counters carry the reproducible run)
SKIP_FIELDS = {"record", "round", "version", "trace_id", "name",
               "kind", "discipline", "schema_sha256", "schema_version",
               "meta", "t_wall_s", "wall_s"}


def _is_int_metric(field: str) -> bool:
    m = schema.METRICS.get(field)
    return m is not None and m.dtype in ("int64", "list[int]", "hist")


def _values(v):
    """Flatten a record value into a list of leaf scalars."""
    if isinstance(v, (list, tuple)):
        out = []
        for x in v:
            out.extend(_values(x))
        return out
    return [v]


def _rel_drift(a, b) -> float:
    av, bv = _values(a), _values(b)
    if len(av) != len(bv):
        return float("inf")
    worst = 0.0
    for x, y in zip(av, bv):
        if isinstance(x, str) or isinstance(y, str):
            if x != y:
                return float("inf")
            continue
        denom = max(abs(float(x)), abs(float(y)), 1e-12)
        worst = max(worst, abs(float(x) - float(y)) / denom)
    return worst


def _align(records):
    """{(rtype, key): record} with positional keys where no natural
    alignment key exists."""
    out, counters = {}, defaultdict(int)
    for r in records:
        rt = r.get("record", "?")
        key_field = ALIGN_KEYS.get(rt)
        key = r.get(key_field) if key_field else None
        if key is None:
            key = counters[rt]
            counters[rt] += 1
        out[(rt, key)] = r
    return out


def diff(recs_a, recs_b, bands, rtol):
    """Returns (per-metric rows, failure list).  A row is
    ``(record_type, metric, n, max_drift, band)``."""
    failures = []

    man_a, man_b = logio.manifest_of(recs_a), logio.manifest_of(recs_b)
    if man_a.get("schema_sha256") != man_b.get("schema_sha256"):
        failures.append(
            "manifest: schema fingerprints differ "
            f"({str(man_a.get('schema_sha256'))[:12]} vs "
            f"{str(man_b.get('schema_sha256'))[:12]}) — the runs "
            "recorded under different schemas; metric comparison is "
            "best-effort")

    a, b = _align(recs_a), _align(recs_b)
    only_a, only_b = set(a) - set(b), set(b) - set(a)
    for rt, key in sorted(only_a, key=str)[:5]:
        failures.append(f"{rt}[{key}]: only in run A")
    for rt, key in sorted(only_b, key=str)[:5]:
        failures.append(f"{rt}[{key}]: only in run B")
    if len(only_a) > 5 or len(only_b) > 5:
        failures.append(f"... {max(len(only_a), len(only_b)) - 5} more "
                        f"unmatched records")

    drift = defaultdict(lambda: [0, 0.0])     # (rt, metric) -> [n, max]
    for k in sorted(set(a) & set(b), key=str):
        ra, rb = a[k], b[k]
        rt = k[0]
        for f in sorted(set(ra) | set(rb)):
            if f in SKIP_FIELDS:
                continue
            if (f in ra) != (f in rb):
                failures.append(f"{rt}[{k[1]}].{f}: present in only "
                                f"one run")
                continue
            d = _rel_drift(ra[f], rb[f])
            ent = drift[(rt, f)]
            ent[0] += 1
            ent[1] = max(ent[1], d)

    rows = []
    for (rt, f), (n, worst) in sorted(drift.items()):
        band = bands.get(f, 0.0 if _is_int_metric(f) else rtol)
        if _is_int_metric(f):
            band = 0.0                 # exact counters: bands never apply
        rows.append((rt, f, n, worst, band))
        if worst > band:
            failures.append(
                f"{rt}.{f}: max drift {worst:.3g} exceeds band "
                f"{band:.3g} (over {n} aligned records)")
    return rows, failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("run_a", help="obs log A (baseline)")
    ap.add_argument("run_b", help="obs log B (candidate)")
    ap.add_argument("--rtol", type=float, default=0.0,
                    help="default relative drift band for float "
                         "metrics (int64 counters are always exact)")
    ap.add_argument("--band", action="append", default=[],
                    metavar="METRIC=RTOL",
                    help="per-metric band override, repeatable")
    args = ap.parse_args()

    bands = {}
    for spec in args.band:
        if "=" not in spec:
            raise SystemExit(f"--band {spec}: want METRIC=RTOL")
        name, val = spec.split("=", 1)
        bands[name] = float(val)

    try:
        recs_a = logio.read_records(args.run_a)
        recs_b = logio.read_records(args.run_b)
    except logio.ObsLogError as e:
        raise SystemExit(str(e))

    rows, failures = diff(recs_a, recs_b, bands, args.rtol)

    if rows:
        w = max(len(f"{rt}.{f}") for rt, f, *_ in rows)
        print(f"{'metric':<{w}}  {'n':>4}  {'max drift':>10}  "
              f"{'band':>8}  status")
        for rt, f, n, worst, band in rows:
            status = "ok" if worst <= band else "FAIL"
            print(f"{rt + '.' + f:<{w}}  {n:>4}  {worst:>10.3g}  "
                  f"{band:>8.3g}  {status}")
    if failures:
        print(f"\n{args.run_a} vs {args.run_b}: "
              f"DRIFT ({len(failures)} failure(s))")
        for msg in failures[:20]:
            print(f"  {msg}")
        return 1
    total = sum(n for _, _, n, _, _ in rows)
    exact = all(worst == 0.0 for _, _, _, worst, _ in rows)
    print(f"\n{args.run_a} vs {args.run_b}: "
          + ("zero drift" if exact else "within bands")
          + f" across {total} aligned metric comparisons")
    return 0


if __name__ == "__main__":
    sys.exit(main())
