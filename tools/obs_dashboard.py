#!/usr/bin/env python
"""Terminal dashboard over an obs run log — live or post-hoc.

Renders the structured record stream (repro.obs) as a compact text
dashboard: loss / clip-fraction sparklines, rounds per second, exact
per-stream byte and energy rates, the staleness histogram, host-span
aggregates, and the serving loop's throughput when the log carries
``serve`` records (`repro.launch.serve --obs-log`).

    python tools/obs_dashboard.py runs/fed.jsonl            # one shot
    python tools/obs_dashboard.py runs/fed.jsonl --follow   # live tail

Follow mode re-reads complete JSONL lines as the run appends them
(a partial final line is simply not yet a record) and redraws every
``--interval`` seconds until interrupted.  Pure stdlib on top of
`repro.obs.logio` — no jax import on the hot path.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from collections import defaultdict
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import logio  # noqa: E402

SPARK = "▁▂▃▄▅▆▇█"
TRAJECTORY = ("round", "sched_event")


def sparkline(values, width=48) -> str:
    """Unicode sparkline of the series, subsampled to ``width``."""
    vals = [float(v) for v in values if v is not None]
    if not vals:
        return "(no data)"
    if len(vals) > width:
        # keep the tail exact: the most recent points matter most
        step = len(vals) / width
        vals = [vals[int(i * step)] for i in range(width - 1)] + vals[-1:]
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    return "".join(SPARK[int((v - lo) / span * (len(SPARK) - 1))]
                   for v in vals)


def _fmt_bytes(n) -> str:
    for unit, scale in (("GiB", 1 << 30), ("MiB", 1 << 20),
                        ("KiB", 1 << 10)):
        if abs(n) >= scale:
            return f"{n / scale:.2f}{unit}"
    return f"{n}B"


def _series(traj, key):
    return [r[key] for r in traj if key in r]


def render(records, path: str) -> str:
    """The full dashboard as one string (idempotent on the records)."""
    by_kind = defaultdict(list)
    for r in records:
        by_kind[r.get("record", "?")].append(r)
    lines = []

    man = logio.manifest_of(records)
    meta = man.get("meta", {})
    head = f"== {path} — schema v{man.get('schema_version', '?')}"
    if meta:
        head += " — " + ", ".join(
            f"{k}={meta[k]}" for k in ("arch", "schedule", "optimizer",
                                       "clients") if k in meta)
    lines.append(head)

    traj = [r for k in TRAJECTORY for r in by_kind.get(k, [])]
    if traj:
        losses = _series(traj, "loss")
        lines.append(f"\nloss      {sparkline(losses)}  "
                     f"last={losses[-1]:.4f} (n={len(losses)})")
        evals = _series(traj, "eval_loss")
        if evals:
            lines.append(f"eval      {sparkline(evals)}  "
                         f"last={evals[-1]:.4f}")
        clips = _series(traj, "clip_fraction")
        if clips:
            lines.append(f"clip_frac {sparkline(clips)}  "
                         f"last={clips[-1]:.3f}")
        stale = _series(traj, "h_staleness")
        if stale:
            lines.append(f"h_stale   {sparkline(stale)}  "
                         f"last={stale[-1]:.0f}")

        # rates: virtual-time for scheduler runs, wall-time for sync
        # rounds that logged wall_s
        n = len(traj)
        last = traj[-1]
        if "time_s" in last and last["time_s"] > 0:
            lines.append(f"\nrounds/sec (virtual): "
                         f"{n / last['time_s']:.3f}  "
                         f"({n} events / {last['time_s']:.2f}s)")
        walls = _series(traj, "wall_s")
        if walls and sum(walls) > 0:
            lines.append(f"rounds/sec (wall):    "
                         f"{len(walls) / sum(walls):.3f}")

        # per-stream byte rates over the run, exact int64 counters
        streams = (("uplink", "cum_uplink_bytes", "uplink_bytes"),
                   ("downlink", "cum_downlink_bytes", "downlink_bytes"),
                   ("hessian_up", "cum_hessian_uplink_bytes",
                    "hessian_uplink_bytes"),
                   ("hessian_dn", "cum_hessian_downlink_bytes",
                    "hessian_downlink_bytes"))
        parts = []
        for label, cum_key, per_key in streams:
            if cum_key in last:
                total = last[cum_key]
            elif per_key in traj[0]:
                total = sum(_series(traj, per_key))
            else:
                continue
            parts.append(f"{label}={_fmt_bytes(total)}"
                         f" ({_fmt_bytes(total // n)}/ev)")
        if parts:
            lines.append("streams:  " + "  ".join(parts))
        energies = _series(traj, "energy_J")
        if energies:
            lines.append(f"energy:   {sum(energies):.3g}J total, "
                         f"{sum(energies) / len(energies):.3g}J/event")

    for summ in by_kind.get("sched_summary", []):
        hist = dict(summ.get("staleness_hist", []))
        lines.append(f"\nscheduler {summ['discipline']}: "
                     f"{summ['events']} events, simulated "
                     f"{summ['final_time_s']:.2f}s, "
                     f"{_fmt_bytes(summ['cum_total_bytes'])} on the wire")
        if hist:
            hi = max(hist.values())
            lines.append("staleness histogram:")
            for k in sorted(hist):
                bar = "#" * max(1, round(hist[k] / hi * 30))
                lines.append(f"  tau={k:<3} {bar} {hist[k]}")

    serve = by_kind.get("serve", [])
    if serve:
        tps = [r["tokens_per_s"] for r in serve]
        last = serve[-1]
        lines.append(f"\nserving   {sparkline(tps)}  "
                     f"last={tps[-1]:.1f} tok/s, batch {last['batch']}, "
                     f"prefill {last['prefill_s'] * 1e3:.0f}ms")
        if "decode_p50_ms" in last:
            lines.append(f"decode latency p50/p95/p99: "
                         f"{last['decode_p50_ms']:.2f}/"
                         f"{last['decode_p95_ms']:.2f}/"
                         f"{last['decode_p99_ms']:.2f} ms")

    spans = by_kind.get("span", [])
    if spans:
        agg = defaultdict(lambda: [0, 0.0])
        for s in spans:
            agg[s["name"]][0] += 1
            agg[s["name"]][1] += s["wall_s"]
        lines.append("\nspans: " + "  ".join(
            f"{name} n={n} mean={tot / n * 1e3:.0f}ms"
            for name, (n, tot) in sorted(agg.items(),
                                         key=lambda kv: -kv[1][1])))

    ndisp = len(by_kind.get("sched_dispatch", []))
    if ndisp:
        lines.append(f"\ntrace: {ndisp} dispatch contexts "
                     f"(tools/obs_trace.py renders the timeline)")
    return "\n".join(lines)


def follow(path: str, interval: float) -> int:
    """Tail the log: parse newly completed lines, redraw, repeat."""
    offset = 0
    records = []
    try:
        while True:
            try:
                with open(path) as f:
                    f.seek(offset)
                    chunk = f.read()
            except OSError:
                chunk = ""
            if chunk:
                lines = chunk.splitlines(keepends=True)
                for line in lines:
                    if not line.endswith("\n"):
                        break          # partial tail: not yet a record
                    offset += len(line)
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        records.append(json.loads(line))
                    except ValueError:
                        pass           # torn write; re-read next pass
            sys.stdout.write("\x1b[2J\x1b[H")   # clear + home
            if records:
                print(render(records, path))
            else:
                print(f"{path}: waiting for records ...")
            print(f"\n[following, every {interval:g}s — Ctrl-C to stop]",
                  flush=True)
            time.sleep(interval)
    except KeyboardInterrupt:
        return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("log", help="obs JSONL run log")
    ap.add_argument("--follow", action="store_true",
                    help="tail a growing log and redraw continuously")
    ap.add_argument("--interval", type=float, default=2.0,
                    help="redraw period in follow mode (seconds)")
    args = ap.parse_args()
    if args.follow:
        return follow(args.log, args.interval)
    try:
        records = logio.read_records(args.log)
    except logio.ObsLogError as e:
        raise SystemExit(str(e))
    print(render(records, args.log))
    return 0


if __name__ == "__main__":
    sys.exit(main())
