# Tier-1 verify targets. `test-fast` is the default CI gate: collection
# plus the fast subset (pytest.ini deselects `slow`), so regressions
# like a hard import of an optional dependency are caught in minutes.
PY := PYTHONPATH=src python

.PHONY: test-fast test-robust test-slow test-all collect bench-comm bench-sched-smoke bench-engine-smoke bench-robust-smoke bench-records-check example-comm docs-check docs-gen obs-smoke obs-trace-smoke autotune autotune-check

test-fast:
	$(PY) -m pytest -q

# the adversarial-fleet harness on its own: degeneracy pins (robust
# aggregation bitwise-identical to the mean path when degenerate,
# across disciplines and comm regimes), kernel-vs-ref conformance,
# attack geometry and the non-IID partitioner statistics
test-robust:
	$(PY) -m pytest -q tests/test_robust.py tests/test_data.py

# fail if README.md / docs/ / benchmarks/README.md reference flags,
# modules, paths or make targets that no longer exist, or if the
# generated docs/configuration.md drifted from the config dataclasses
# (stdlib-only)
docs-check:
	python tools/check_docs.py

# regenerate docs/configuration.md from the config dataclasses
docs-gen:
	python tools/gen_config_docs.py

# re-sweep the Pallas block-size table (src/repro/kernels/tuning.json)
# at the committed benchmark sizes; commit the result
autotune:
	$(PY) tools/autotune_kernels.py

# CI gate on the committed tuning table: keys must equal the
# repro.kernels.KERNELS registry and every kernel must compile + run
# with its committed blocks on CPU, bitwise equal to the default
# launch geometry
autotune-check:
	$(PY) tools/autotune_kernels.py --check

test-slow:
	$(PY) -m pytest -q -m slow

test-all:
	$(PY) -m pytest -q -m ""

collect:
	$(PY) -m pytest -q --collect-only > /dev/null

bench-comm:
	$(PY) -m benchmarks.run --only comm

# CI-sized scheduler regime: sync vs semisync vs async on the virtual
# clock, tiny budgets (same code path as the full `--only sched` run)
bench-sched-smoke:
	$(PY) -m benchmarks.run --only sched --smoke --out ""

# CI gate on the flat-resident round engine: recount the
# layout-conversion ops in the jitted round jaxpr (no timing, no file
# write) and FAIL if any gated regime regressed vs the committed
# trajectory in BENCH_engine.json
bench-engine-smoke:
	$(PY) -m benchmarks.run --only engine --smoke --out ""

# CI-sized adversarial-fleet regime: non-IID partitions + byzantine
# sign-flip vs robust aggregation, tiny budgets (same code path as the
# full `--only robust` run behind experiments/bench_robust.json)
bench-robust-smoke:
	$(PY) -m benchmarks.run --only robust --smoke --out ""

# CI gate on the obs pipeline: a 2-round scheduled run with Sophia
# health probes writing schema-validated JSONL, then re-validate every
# record (manifest header, field sets, exact-int64 byte counters)
obs-smoke:
	$(PY) -m repro.launch.train --arch minicpm-2b --reduced --rounds 2 \
		--clients 2 --local-iters 1 --batch 1 --seq 16 \
		--schedule semisync --latency-profile straggler \
		--probes --obs-log /tmp/obs_smoke.jsonl
	python tools/obs_report.py /tmp/obs_smoke.jsonl --validate

# CI gate on the tracing + observatory layer: the same 2-round
# semisync run with per-dispatch trace contexts on, exported as
# Chrome Trace / Perfetto JSON and structurally validated (required
# keys per event, non-negative durations, monotonic timestamps per
# lane), then an obs_diff self-compare that must report zero drift
obs-trace-smoke:
	$(PY) -m repro.launch.train --arch minicpm-2b --reduced --rounds 2 \
		--clients 2 --local-iters 1 --batch 1 --seq 16 \
		--schedule semisync --latency-profile straggler \
		--probes --trace --obs-log /tmp/obs_trace_smoke.jsonl
	python tools/obs_trace.py /tmp/obs_trace_smoke.jsonl --validate
	python tools/obs_diff.py /tmp/obs_trace_smoke.jsonl \
		/tmp/obs_trace_smoke.jsonl

# CI gate on the committed benchmark trajectories: every row of
# experiments/bench_*.json and BENCH_engine.json must be a
# schema-valid obs `bench` record behind a current-version manifest
# (they are regenerated through the recorder by benchmarks.run)
bench-records-check:
	python tools/obs_report.py experiments/bench_comm.json --validate
	python tools/obs_report.py experiments/bench_sched.json --validate
	python tools/obs_report.py experiments/bench_robust.json --validate
	python tools/obs_report.py BENCH_engine.json --validate

example-comm:
	$(PY) examples/comm_compression.py
