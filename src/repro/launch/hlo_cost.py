"""Loop-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
undercounts scan-over-layers / local-iteration / kv-chunk loops by their
trip counts. This analyzer parses the post-SPMD HLO module, walks the call
graph (entry -> calls/fusions/whiles/conditionals), extracts while trip
counts from their condition computations, and accumulates:

  * flops            — dot/convolution from shapes (2*M*N*K), elementwise ~1/elem
  * bytes            — operands+outputs of top-level (fusion-boundary) ops
  * collective bytes — per kind, ring-algorithm accounting (see roofline.py)

Conditionals are counted at the max over branches (conservative: the GNB
branch runs on Hessian-refresh steps).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+"
    r"([a-z][a-z0-9\-]*)\((.*)$")
_CALLED_RE = re.compile(
    r"(?:body|condition|to_apply|called_computations=\{|calls)="
    r"?%?([\w.\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")


def _shape_list(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _bytes_of(shapes) -> int:
    return sum(_DTYPE_BYTES[dt] * (math.prod(dims) if dims else 1)
               for dt, dims in shapes)


@dataclass
class Op:
    name: str
    opcode: str
    out_shapes: list
    operand_names: list
    attrs: str
    called: List[str] = field(default_factory=list)
    body: Optional[str] = None
    condition: Optional[str] = None
    raw: str = ""


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("{" in line):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry_name = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, out_txt, opcode, rest = m.groups()
        # split rest at the closing paren of the operand list
        depth, idx = 1, 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operands_txt, attrs = rest[:idx], rest[idx + 1:]
        called = []
        for cm in re.finditer(
                r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)", attrs):
            called.append(cm.group(1))
        fm = re.search(r"called_computations=\{([^}]*)\}", attrs)
        if fm:
            called += [c.strip().lstrip("%")
                       for c in fm.group(1).split(",") if c.strip()]
        opnames = re.findall(r"%([\w.\-]+)", operands_txt)
        bm = re.search(r"body=%?([\w.\-]+)", attrs)
        cm2 = re.search(r"condition=%?([\w.\-]+)", attrs)
        cur.ops.append(Op(name, opcode, _shape_list(out_txt),
                          opnames, attrs, called,
                          body=bm.group(1) if bm else None,
                          condition=cm2.group(1) if cm2 else None,
                          raw=line))
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def build_symbols(comps) -> Dict[str, list]:
    """op name -> output shape list (names are module-unique)."""
    table: Dict[str, list] = {}
    for comp in comps.values():
        for op in comp.ops:
            table[op.name] = op.out_shapes
    return table


def _while_trip_count(comps, cond_name: str) -> int:
    """Heuristic: largest integer constant in the condition computation
    (our scans lower to `i < N`). Falls back to 1."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    # constants appear as: %c = s32[] constant(40)
    for op in cond.ops:
        for m in re.finditer(r"constant\((\d+)\)", op.raw):
            best = max(best, int(m.group(1)))
    return best


_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _collective_moved(opcode: str, out_b: int, in_b: int) -> float:
    base = opcode.replace("-start", "")
    if base == "all-gather":
        return max(out_b - in_b, 0)
    if base == "all-reduce":
        return 2.0 * in_b
    return float(in_b)


def _dot_flops(op: Op, operand_shapes) -> float:
    out_elems = sum(math.prod(d) if d else 1 for _, d in op.out_shapes)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    k = 1
    if m and operand_shapes:
        lhs_dims = operand_shapes[0][1]
        for i in m.group(1).split(","):
            if i and int(i) < len(lhs_dims):
                k *= lhs_dims[int(i)]
    return 2.0 * out_elems * k


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self.symbols = build_symbols(self.comps)
        self._memo: Dict[Tuple[str, bool], dict] = {}

    def _operand_shapes(self, op: Op) -> list:
        out = []
        for n in op.operand_names:
            out.extend(self.symbols.get(n, []))
        return out

    def _fusion_effective_bytes(self, op: Op) -> Tuple[float, float]:
        """Effective HBM (read, write) bytes of a fusion.

        * A parameter whose only in-fusion uses are dynamic-slice/gather
          (operand 0) is read slice-wise — KV caches / scan xs buffers.
        * A root (or root-tuple element) that is a dynamic-update-slice
          writes only the update slice — XLA aliases the buffer in-place
          (scan ys accumulation) — and the aliased input param is free.
        """
        out_full = float(_bytes_of(op.out_shapes))
        comp = self.comps.get(op.called[0]) if op.called else None
        if comp is None or not comp.ops:
            return float(_bytes_of(self._operand_shapes(op))), out_full
        pidx = {}
        for o in comp.ops:
            if o.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", o.raw)
                if m:
                    pidx[o.name] = int(m.group(1))
        byname = {o.name: o for o in comp.ops}
        # ---- outputs: root DUS elements write slice-wise, alias their dst
        root = comp.ops[-1]
        roots = ([byname[n] for n in root.operand_names if n in byname]
                 if root.opcode == "tuple" else [root])
        out_eff, aliased = 0.0, set()
        for r in roots:
            if r.opcode == "dynamic-update-slice" and len(r.operand_names) >= 2:
                upd = byname.get(r.operand_names[1])
                out_eff += float(_bytes_of(upd.out_shapes)) if upd else 0.0
                dst = r.operand_names[0]
                if dst in pidx:
                    aliased.add(dst)
            else:
                out_eff += float(_bytes_of(r.out_shapes))
        if root.opcode != "tuple" and not roots:
            out_eff = out_full
        out_eff = min(out_eff, out_full) if roots else out_full
        # ---- inputs: slice-wise params
        eff = {}
        for o in comp.ops:
            for n in o.operand_names:
                if n not in pidx:
                    continue
                if o.opcode in ("dynamic-slice", "gather", "slice") \
                        and o.operand_names and o.operand_names[0] == n:
                    cur = eff.get(n)
                    if cur is None or cur[0] == "slice":
                        eff[n] = ("slice",
                                  (cur[1] if cur else 0.0)
                                  + _bytes_of(o.out_shapes))
                elif o.opcode == "dynamic-update-slice" \
                        and o.operand_names and o.operand_names[0] == n \
                        and o in roots:
                    pass                      # aliased destination
                else:
                    eff[n] = ("full", None)
        tot = 0.0
        for name, idx in pidx.items():
            if name in aliased and eff.get(name, ("x",))[0] != "full":
                continue
            opname = (op.operand_names[idx]
                      if idx < len(op.operand_names) else None)
            full_b = float(_bytes_of(self.symbols.get(opname, [])))
            kind = eff.get(name, ("full", None))
            tot += min(kind[1], full_b) if kind[0] == "slice" else full_b
        return tot, out_eff

    def _zero(self):
        return {"flops": 0.0, "bytes": 0.0,
                "collectives": {k: 0.0 for k in _COLL_KINDS},
                "by_opcode": {}}

    def _add(self, a, b, scale=1.0):
        a["flops"] += b["flops"] * scale
        a["bytes"] += b["bytes"] * scale
        for k in _COLL_KINDS:
            a["collectives"][k] += b["collectives"][k] * scale
        for k, (f, by) in b["by_opcode"].items():
            cf, cb = a["by_opcode"].get(k, (0.0, 0.0))
            a["by_opcode"][k] = (cf + f * scale, cb + by * scale)

    @staticmethod
    def _tally(total, opcode, flops, byts):
        total["flops"] += flops
        total["bytes"] += byts
        f, b = total["by_opcode"].get(opcode, (0.0, 0.0))
        total["by_opcode"][opcode] = (f + flops, b + byts)

    def analyze(self, comp_name: str = "__entry__",
                inside_fusion: bool = False) -> dict:
        key = (comp_name, inside_fusion)
        if key in self._memo:
            return self._memo[key]
        total = self._zero()
        comp = self.comps.get(comp_name)
        if comp is None:
            return total
        self._memo[key] = total          # guard cycles
        for op in comp.ops:
            oc = op.opcode
            operand_shapes = self._operand_shapes(op)
            out_b = _bytes_of(op.out_shapes)
            in_b = _bytes_of(operand_shapes)
            base = oc.replace("-start", "").replace("-done", "")
            if oc.endswith("-done"):
                continue
            if base in _COLL_KINDS:
                total["collectives"][base] += _collective_moved(
                    oc, out_b, in_b)
                self._tally(total, base, 0.0, out_b + in_b)
            elif oc in ("dot", "dot-general"):
                self._tally(total, "dot", _dot_flops(op, operand_shapes),
                            0.0 if inside_fusion else out_b + in_b)
            elif oc == "convolution":
                # approximate: 2 * out_elems * kernel-elems-per-out-channel
                if len(operand_shapes) > 1 and operand_shapes[1][1]:
                    kdims = operand_shapes[1][1]
                    ratio = math.prod(kdims) / max(kdims[-1], 1)
                else:
                    ratio = 1
                out_e = sum(math.prod(d) if d else 1
                            for _, d in op.out_shapes)
                self._tally(total, oc, 2.0 * out_e * ratio,
                            0.0 if inside_fusion else out_b + in_b)
            elif oc in ("dynamic-slice", "gather", "slice"):
                # reads only the slice it extracts (+ writes it): NOT the
                # full operand buffer — scan xs/cache lookups hit this.
                self._tally(total, oc, 0.0,
                            0.0 if inside_fusion else 2.0 * out_b)
            elif oc in ("dynamic-update-slice", "scatter"):
                # in-place: read update slice + write the region it covers.
                upd_i = 1 if oc == "dynamic-update-slice" else 2
                upd_b = (_bytes_of(operand_shapes[upd_i:upd_i + 1])
                         if len(operand_shapes) > upd_i else out_b)
                self._tally(total, oc, 0.0,
                            0.0 if inside_fusion else 2.0 * upd_b)
            elif oc == "fusion":
                sub = self.analyze(op.called[0], True) if op.called \
                    else self._zero()
                self._add(total, sub)
                # fusion boundary traffic: slice-wise reads for operands
                # only dynamic-sliced inside; slice-wise writes for
                # in-place dynamic-update-slice roots (scan accumulators).
                eff_in, eff_out = (self._fusion_effective_bytes(op)
                                   if op.called else (in_b, out_b))
                self._tally(total, "fusion", 0.0, eff_out + eff_in)
            elif oc == "while":
                trips = (_while_trip_count(self.comps, op.condition)
                         if op.condition else 1)
                sub = (self.analyze(op.body, False) if op.body
                       else self._zero())
                self._add(total, sub, scale=trips)
            elif oc == "conditional":
                branches = [self.analyze(c, False) for c in op.called]
                if branches:
                    best = max(branches, key=lambda s: s["flops"])
                    self._add(total, best)
            elif oc in ("call", "custom-call", "async-start"):
                for c in op.called:
                    self._add(total, self.analyze(c, inside_fusion))
                if oc == "custom-call" and not inside_fusion:
                    total["bytes"] += out_b + in_b
            else:
                # elementwise & misc: ~1 flop/elem; bytes at top level only
                total["flops"] += sum(math.prod(d) if d else 1
                                      for _, d in op.out_shapes)
                if not inside_fusion and oc not in (
                        "parameter", "constant", "tuple",
                        "get-tuple-element", "bitcast"):
                    total["bytes"] += out_b + in_b
        self._memo[key] = total
        return total

    def top_contributors(self, n: int = 25) -> List[dict]:
        """Heaviest individual ops (bytes x loop-trip scale). Walks the call
        tree with the accumulated trip multiplier so a fusion inside a
        48-layer scan x 128-chunk scan shows its true total."""
        acc: Dict[str, dict] = {}

        def walk(comp_name: str, scale: float, inside_fusion: bool,
                 depth: int = 0):
            comp = self.comps.get(comp_name)
            if comp is None or depth > 40:
                return
            for op in comp.ops:
                oc = op.opcode
                base = oc.replace("-start", "").replace("-done", "")
                if oc.endswith("-done"):
                    continue
                out_b = _bytes_of(op.out_shapes)
                in_b = _bytes_of(self._operand_shapes(op))
                byts = flops = 0.0
                if base in _COLL_KINDS:
                    byts = out_b + in_b
                elif oc in ("dot", "dot-general"):
                    flops = _dot_flops(op, self._operand_shapes(op))
                    byts = 0 if inside_fusion else out_b + in_b
                elif oc in ("dynamic-slice", "gather", "slice"):
                    byts = 0 if inside_fusion else 2.0 * out_b
                elif oc in ("dynamic-update-slice", "scatter"):
                    sh = self._operand_shapes(op)
                    i = 1 if oc == "dynamic-update-slice" else 2
                    byts = 0 if inside_fusion else 2.0 * _bytes_of(
                        sh[i:i + 1] if len(sh) > i else op.out_shapes)
                elif oc == "fusion":
                    eff_in, eff_out = (self._fusion_effective_bytes(op)
                                       if op.called else (in_b, out_b))
                    byts = eff_out + eff_in
                    walk(op.called[0], scale, True, depth + 1) \
                        if op.called else None
                elif oc == "while":
                    trips = (_while_trip_count(self.comps, op.condition)
                             if op.condition else 1)
                    if op.body:
                        walk(op.body, scale * trips, False, depth + 1)
                elif oc == "conditional":
                    for c in op.called:
                        walk(c, scale, False, depth + 1)
                elif oc in ("call", "custom-call", "async-start"):
                    for c in op.called:
                        walk(c, scale, inside_fusion, depth + 1)
                    if oc == "custom-call" and not inside_fusion:
                        byts = out_b + in_b
                else:
                    flops = sum(math.prod(d) if d else 1
                                for _, d in op.out_shapes)
                    if inside_fusion or oc in (
                            "parameter", "constant", "tuple",
                            "get-tuple-element", "bitcast"):
                        byts = 0
                    else:
                        byts = out_b + in_b
                if byts * scale or flops * scale:
                    key = op.name
                    e = acc.setdefault(key, dict(
                        name=op.name, opcode=oc, bytes=0.0, flops=0.0,
                        scale=scale,
                        shape=op.raw.split("=")[1].strip()[:60] if "=" in op.raw else ""))
                    e["bytes"] += byts * scale
                    e["flops"] += flops * scale

        walk("__entry__", 1.0, False)
        return sorted(acc.values(), key=lambda e: -e["bytes"])[:n]

    def summary(self) -> dict:
        res = self.analyze()
        out = {"flops": res["flops"], "bytes": res["bytes"],
               "collectives": dict(res["collectives"])}
        out["collective_total"] = sum(out["collectives"].values())
        out["bytes_by_opcode"] = dict(sorted(
            ((k, round(v[1])) for k, v in res["by_opcode"].items()),
            key=lambda kv: -kv[1])[:12])
        out["flops_by_opcode"] = dict(sorted(
            ((k, round(v[0])) for k, v in res["by_opcode"].items()),
            key=lambda kv: -kv[1])[:8])
        return out
