"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / ICI link bw

cost_analysis() reports the per-device (post-SPMD) module, so the
"/ chips" in the spec formulas is already applied. Collective bytes are
parsed from the partitioned HLO text with ring-algorithm accounting:

  all-gather          output - operand     (bytes received per device)
  reduce-scatter      operand bytes        (bytes sent per device)
  all-reduce          2 x operand bytes    (reduce-scatter + all-gather)
  all-to-all          operand bytes
  collective-permute  operand bytes
"""
from __future__ import annotations

import re
from typing import Dict

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\b")

_MULT = {"all-reduce": 2.0, "all-gather": -1.0,  # output - operand
         "reduce-scatter": 1.0, "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(dtype: str, dims: str) -> int:
    bs = _DTYPE_BYTES.get(dtype)
    if bs is None:
        return 0                       # token types etc.
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * bs


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved over ICI, by collective kind."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "=" not in line:
            continue
        if "-done" in line[m.start():m.end() + 6]:
            continue                   # -done pairs with -start; count once
        kind = m.group(1)
        lhs, _, rhs = line.partition("=")
        rhs_head, _, rhs_args = rhs.partition("(")
        out_bytes = sum(_shape_bytes(d, s)
                        for d, s in _SHAPE_RE.findall(rhs_head))
        operand_bytes = sum(_shape_bytes(d, s)
                            for d, s in _SHAPE_RE.findall(rhs_args))
        if kind == "all-gather":
            moved = max(out_bytes - operand_bytes, 0)
        else:
            moved = _MULT[kind] * operand_bytes
        out[kind] = out.get(kind, 0.0) + moved
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    terms = {
        "compute_s": flops_per_dev / PEAK_FLOPS_BF16,
        "memory_s": bytes_per_dev / HBM_BW,
        "collective_s": coll_bytes_per_dev / ICI_BW,
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    ).replace("_s", "")
    return terms


# --------------------------------------------------------------------------
# MODEL_FLOPS (useful work) per entry point
# --------------------------------------------------------------------------

def count_params(cfg) -> Dict[str, float]:
    """Total and active (MoE top-k) parameter counts from shapes alone."""
    import jax

    from repro.models import transformer as T

    params = jax.eval_shape(lambda k: T.init_lm(k, cfg),
                            jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    total = expert = 0
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if cfg.moe is not None and "ffn" in pstr and "shared" not in pstr \
                and pstr.split("/")[-1] in ("w_gate", "w_up", "w_down"):
            expert += n
    active = total - expert
    if cfg.moe is not None and expert:
        active += expert * cfg.moe.top_k / cfg.moe.num_experts
    return {"total": float(total), "active": float(active)}


def model_flops(cfg, shape_name: str, *, local_iters: int = 10) -> float:
    from repro.configs.base import INPUT_SHAPES
    shape = INPUT_SHAPES[shape_name]
    n = count_params(cfg)["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len * local_iters
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token each
