"""Serving launcher: batched prefill + decode loop for any decoder arch.

    PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b \
        --reduced --prompt-len 16 --gen 8 --batch 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs, obs
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace (annotated "
                         "prefill/decode spans) into this directory")
    ap.add_argument("--obs-log", default="",
                    help="write structured `serve` records (tokens/sec, "
                         "prefill/decode latency percentiles) to this "
                         "JSONL; render with tools/obs_dashboard.py")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_model_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(d_model=128)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode")
    key = jax.random.PRNGKey(args.seed)
    params = T.init_lm(key, cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G

    if cfg.embedding_inputs:
        prompt = {"embeds": jax.random.normal(
            key, (B, P, cfg.d_model), dtype=T.param_dtype(cfg))}
    else:
        prompt = {"tokens": jax.random.randint(key, (B, P), 0,
                                               cfg.vocab_size)}

    recorder = None
    if args.obs_log:
        recorder = obs.RunRecorder(
            args.obs_log,
            meta={"arch": cfg.name, "batch": B, "prompt_len": P,
                  "gen": G, "mode": "serve"})

    prof = obs.profile_trace(args.profile_dir)
    prof.__enter__()
    t0 = time.time()
    with obs.annotate("prefill"):
        logits, cache, _ = T.forward(params, cfg, prompt, want_cache=True,
                                     remat=False)
        cache = T.prefill_to_decode_cache(cfg, cache, P, max_len)
        if recorder is not None:
            jax.block_until_ready(cache)
    prefill_s = time.time() - t0
    print(f"prefill ({B}x{P}): {prefill_s:.2f}s")

    decode = jax.jit(lambda p, b, c, pos: T.decode_step(p, cfg, b, c, pos))
    tok = T.sample_labels(jax.random.fold_in(key, 99),
                          logits[:, -1] / args.temperature, cfg.vocab_size)
    out_tokens = [tok]
    step_ms = []
    t0 = time.time()
    for i in range(G - 1):
        ts = time.time()
        pos = jnp.asarray(P + i, jnp.int32)
        if cfg.embedding_inputs:
            step_in = {"embeds": params["embed"][tok][:, None, :]}
        else:
            step_in = {"tokens": tok[:, None]}
        with obs.annotate("decode_step"):
            lg, cache = decode(params, step_in, cache, pos)
        tok = T.sample_labels(jax.random.fold_in(key, 100 + i),
                              lg[:, -1] / args.temperature, cfg.vocab_size)
        out_tokens.append(tok)
        if recorder is not None:
            # per-step percentiles need a per-step sync; the unlogged
            # loop keeps its fully-async dispatch
            jax.block_until_ready(tok)
            step_ms.append((time.time() - ts) * 1e3)
    dt = time.time() - t0
    prof.__exit__(None, None, None)
    toks = jnp.stack(out_tokens, axis=1)
    tok_s = G * B / max(dt, 1e-9)
    print(f"decoded {G} tokens x {B} seqs in {dt:.2f}s "
          f"({tok_s:.1f} tok/s)")
    print("sampled token ids:", toks.tolist())
    if recorder is not None:
        rec = {"record": "serve", "tokens_per_s": tok_s,
               "prefill_s": prefill_s, "decode_steps": G, "batch": B}
        if step_ms:
            q = sorted(step_ms)

            def pct(p):
                return q[min(len(q) - 1, int(round(p * (len(q) - 1))))]

            rec.update(decode_p50_ms=pct(0.50), decode_p95_ms=pct(0.95),
                       decode_p99_ms=pct(0.99))
        recorder.emit(rec)
        recorder.close()
        print(f"wrote {recorder.counts} obs records to {args.obs_log}")


if __name__ == "__main__":
    main()
