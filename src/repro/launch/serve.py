"""Serving launcher: batched prefill + decode loop for any decoder arch.

    PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b \
        --reduced --prompt-len 16 --gen 8 --batch 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs, obs
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace (annotated "
                         "prefill/decode spans) into this directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_model_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(d_model=128)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode")
    key = jax.random.PRNGKey(args.seed)
    params = T.init_lm(key, cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G

    if cfg.embedding_inputs:
        prompt = {"embeds": jax.random.normal(
            key, (B, P, cfg.d_model), dtype=T.param_dtype(cfg))}
    else:
        prompt = {"tokens": jax.random.randint(key, (B, P), 0,
                                               cfg.vocab_size)}

    prof = obs.profile_trace(args.profile_dir)
    prof.__enter__()
    t0 = time.time()
    with obs.annotate("prefill"):
        logits, cache, _ = T.forward(params, cfg, prompt, want_cache=True,
                                     remat=False)
        cache = T.prefill_to_decode_cache(cfg, cache, P, max_len)
    print(f"prefill ({B}x{P}): {time.time() - t0:.2f}s")

    decode = jax.jit(lambda p, b, c, pos: T.decode_step(p, cfg, b, c, pos))
    tok = T.sample_labels(jax.random.fold_in(key, 99),
                          logits[:, -1] / args.temperature, cfg.vocab_size)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(G - 1):
        pos = jnp.asarray(P + i, jnp.int32)
        if cfg.embedding_inputs:
            step_in = {"embeds": params["embed"][tok][:, None, :]}
        else:
            step_in = {"tokens": tok[:, None]}
        with obs.annotate("decode_step"):
            lg, cache = decode(params, step_in, cache, pos)
        tok = T.sample_labels(jax.random.fold_in(key, 100 + i),
                              lg[:, -1] / args.temperature, cfg.vocab_size)
        out_tokens.append(tok)
    dt = time.time() - t0
    prof.__exit__(None, None, None)
    toks = jnp.stack(out_tokens, axis=1)
    print(f"decoded {G} tokens x {B} seqs in {dt:.2f}s "
          f"({G * B / max(dt, 1e-9):.1f} tok/s)")
    print("sampled token ids:", toks.tolist())


if __name__ == "__main__":
    main()
