"""Production mesh construction (TPU v5e pods; CPU placeholder devices in
the dry-run). Functions, not module-level constants, so importing never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_small_mesh(*, multi_pod: bool = False):
    """Reduced-footprint mesh for tests (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def client_axes(mesh) -> tuple:
    """Clients lay out over (pod, data): in-pod mean then cross-pod mean =
    the hierarchical PS aggregation of DESIGN.md §3."""
    return data_axes(mesh)


# TPU v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
