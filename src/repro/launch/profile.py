"""Dry-run profiler: lower one (arch x shape x mesh) combo and print the
heaviest individual HLO ops (bytes x loop-trip scale) — the §Perf
hypothesis-forming view.

    PYTHONPATH=src python -m repro.launch.profile --arch xlstm-1.3b \
        --shape prefill_32k [--multi-pod] [--top 25] [--dump-hlo out.txt]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

import argparse

import jax

from repro.configs.base import INPUT_SHAPES
from repro.launch import api
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_cost import HloCost
from repro.launch.roofline import roofline_terms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--optimizer", default="fed_sophia")
    ap.add_argument("--local-iters", type=int, default=10)
    ap.add_argument("--dump-hlo", default="")
    ap.add_argument("--overrides", default="")
    args = ap.parse_args()

    from repro.launch.dryrun import parse_overrides
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    kw = {"cfg_overrides": parse_overrides(args.overrides)}
    if INPUT_SHAPES[args.shape].kind == "train":
        kw.update(optimizer=args.optimizer, local_iters=args.local_iters)
    bundle = api.build(args.arch, args.shape, mesh, **kw)
    with mesh:
        lowered = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                          out_shardings=bundle.out_shardings)
        compiled = lowered.lower(*bundle.args).compile()
        hlo = compiled.as_text()
    if args.dump_hlo:
        with open(args.dump_hlo, "w") as f:
            f.write(hlo)
        print(f"HLO -> {args.dump_hlo} ({len(hlo)} chars)")
    hc = HloCost(hlo)
    s = hc.summary()
    terms = roofline_terms(s["flops"], s["bytes"], s["collective_total"])
    print(f"flops/dev={s['flops']:.3g}  bytes/dev={s['bytes']:.3g}  "
          f"coll/dev={s['collective_total']:.3g}")
    print("roofline:", {k: (f"{v:.4g}" if isinstance(v, float) else v)
                        for k, v in terms.items()})
    print("\nbytes by opcode:")
    for k, v in s["bytes_by_opcode"].items():
        print(f"  {k:24s} {v:.4g}")
    print(f"\ntop {args.top} ops by bytes (scale = loop trip multiplier):")
    hdr = f"{'bytes':>12s} {'flops':>12s} {'scale':>8s} {'opcode':20s} shape"
    print(hdr)
    for e in hc.top_contributors(args.top):
        print(f"{e['bytes']:12.4g} {e['flops']:12.4g} {e['scale']:8.0f} "
              f"{e['opcode']:20s} {e['shape'][:70]}")


if __name__ == "__main__":
    main()
