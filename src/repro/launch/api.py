"""Builds the jit-able entry point + arg structures + shardings for every
(architecture x input-shape x mesh) combination.

Everything returns ShapeDtypeStruct stand-ins (no device allocation) so the
dry-run can .lower().compile() the production meshes on CPU placeholders.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import (CommConfig, FedConfig, INPUT_SHAPES,
                                ModelConfig, ShapeConfig)
from repro.core.fed import FedEngine
from repro.launch.mesh import client_axes, data_axes
from repro.models import transformer as T
from repro.sharding import specs as S

FULL_ATTENTION_ARCHS = {
    "qwen3-moe-235b-a22b", "minicpm-2b", "qwen3-14b",
    "deepseek-v2-lite-16b", "qwen2-vl-2b", "chatglm3-6b",
}
ENCODER_ONLY_ARCHS = {"hubert-xlarge"}


def applicable(arch_id: str, shape_name: str) -> Tuple[bool, str]:
    """Shape/arch skip rules (recorded in DESIGN.md §5)."""
    shape = INPUT_SHAPES[shape_name]
    if arch_id in ENCODER_ONLY_ARCHS and shape.kind == "decode":
        return False, "encoder-only: no decode step"
    if shape_name == "long_500k" and arch_id in FULL_ATTENTION_ARCHS:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic mixing"
    return True, ""


@dataclass
class Bundle:
    """Everything the dry-run / launcher needs for one combination."""
    fn: Callable
    args: tuple                 # ShapeDtypeStructs (or concrete arrays)
    in_shardings: tuple
    out_shardings: Any
    meta: Dict[str, Any]


def _sds(tree, shardings=None):
    """pytree -> ShapeDtypeStruct pytree (optionally sharding-annotated)."""
    if shardings is None:
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        tree, shardings)


def _replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def resolve_fed(arch_id: str, mesh, *, local_iters: int = 10) -> FedConfig:
    over = dict(configs.get_fed_overrides(arch_id))
    strategy = over.pop("strategy", "parallel")
    caxes = client_axes(mesh)
    csize = 1
    for a in caxes:
        csize *= mesh.shape[a]
    if strategy == "parallel":
        num_clients = csize
    else:
        num_clients = 8
    # sequential runs give each client the full mesh; persistent per-client
    # EMAs would cost C x 2|theta| HBM -> stateless mode (DESIGN.md §4)
    persistent = over.pop("persistent_client_state",
                          strategy != "sequential")
    return FedConfig(num_clients=num_clients, local_iters=local_iters,
                     optimizer="fed_sophia", strategy=strategy,
                     persistent_client_state=persistent,
                     tau=10, **over)


# --------------------------------------------------------------------------
# builders
# --------------------------------------------------------------------------

def _batch_struct(cfg: ModelConfig, lead_dims: tuple, seq: int):
    dtype = T.param_dtype(cfg)
    out = {}
    if cfg.embedding_inputs:
        out["embeds"] = jnp.zeros(lead_dims + (seq, cfg.d_model), dtype)
    else:
        out["tokens"] = jnp.zeros(lead_dims + (seq,), jnp.int32)
    return out


def _apply_overrides(cfg: ModelConfig, over: Optional[dict]) -> ModelConfig:
    if not over:
        return cfg
    typed = {}
    for k, v in over.items():
        cur = getattr(cfg, k)
        if isinstance(v, str) and cur is not None:
            if isinstance(cur, bool):
                v = v.lower() in ("1", "true", "yes")
            elif isinstance(cur, (int, float, str)):
                v = type(cur)(v)
        typed[k] = v
    return dataclasses.replace(cfg, **typed)


def build_train(arch_id: str, mesh, *, reduced: bool = False,
                local_iters: int = 10, optimizer: str = "fed_sophia",
                use_pallas: bool = False, fsdp_gather: bool = True,
                cfg_overrides: Optional[dict] = None,
                fed_overrides: Optional[dict] = None,
                comm: Optional[CommConfig] = None,
                packed_state: bool = False) -> Bundle:
    cfg = _apply_overrides(configs.get_model_config(arch_id), cfg_overrides)
    shape = INPUT_SHAPES["train_4k"]
    seq, gbatch = shape.seq_len, shape.global_batch
    if reduced:
        cfg = cfg.reduced(d_model=128)
        seq, gbatch = 32, 16
    fed = resolve_fed(arch_id, mesh, local_iters=local_iters)
    if optimizer != "fed_sophia":
        fed = dataclasses.replace(fed, optimizer=optimizer)
    if fed_overrides:
        typed = {k: (type(getattr(fed, k))(v)
                     if isinstance(v, str) and not isinstance(
                         getattr(fed, k), (bool, str)) else v)
                 for k, v in fed_overrides.items()}
        fed = dataclasses.replace(fed, **typed)
    if use_pallas:
        fed = dataclasses.replace(fed, use_pallas=True)
    if comm is not None:
        fed = dataclasses.replace(fed, comm=comm)
    task = T.LMTask(cfg)
    seq_fed0 = fed.strategy == "sequential"
    gather_sh = None
    if seq_fed0 and fsdp_gather:
        # FSDP storage sharding is (model x data); every USE of the params
        # must see the model-only sharding or GSPMD replicates the
        # batch-sharded activations over data instead (see FedEngine).
        p_struct = jax.eval_shape(lambda k: T.init_lm(k, cfg),
                                  jax.random.PRNGKey(0))
        gather_sh = S.param_shardings(cfg, mesh, p_struct, fsdp_axes=None)
    engine = FedEngine(task, fed, gather_shardings=gather_sh)

    C = fed.num_clients
    caxes = client_axes(mesh)
    daxes = data_axes(mesh)
    seq_fed = fed.strategy == "sequential"
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    # sequential shards the per-client batch over the data axes
    b = max(gbatch // C, dsize if seq_fed else 1)

    state = jax.eval_shape(engine.init, jax.random.PRNGKey(0))
    p_sh = S.param_shardings(cfg, mesh, state["params"],
                             fsdp_axes=daxes if seq_fed else None)
    if packed_state:
        # packed-resident mode: the state ships to the device with
        # params (and FedOpt m/v) already in wire layout — the round
        # neither packs nor unpacks them
        state = jax.eval_shape(engine.pack_state, state)
    st_sh = {"params": p_sh,
             "round": NamedSharding(mesh, P())}
    # ALL per-client engine state lives in wire layout (C, rows, cols)
    # — the Sophia m/h EMAs, the uplink EF residuals, the per-client
    # downlink model replicas and the server-side downlink EF — so one
    # sharding rule covers everything: clients over the client axes,
    # and the cols axis over the remaining (model) axes in parallel
    # mode — the wire-layout analogue of the old per-leaf param
    # shardings, so the 2 x C x |theta| optimizer state is never
    # replicated across the model axes.  cols (= quant_block, a power
    # of two) is the divisible axis; rows = ceil(n/cols) generally is
    # not.  Under sequential/FSDP, cols shard over the data axes
    # instead (ZeRO-style, mirroring the params' fsdp_axes — note
    # resolve_fed disables persistent client state for sequential, so
    # client_opt only exists there under an explicit override).
    maxes = tuple(n for n in mesh.axis_names if n not in daxes)
    wire_sh = NamedSharding(
        mesh, P(caxes, None, maxes or None) if not seq_fed
        else P(None, None, daxes))
    if "client_opt" in state:
        from repro.core.sophia import SophiaState
        st_sh["client_opt"] = SophiaState(m=wire_sh, h=wire_sh)
    from repro.comm.downlink import EF_KEY, MODEL_KEY
    for k in ("comm_ef", MODEL_KEY, EF_KEY):
        if k in state:
            st_sh[k] = wire_sh
    if packed_state:
        # the flat analogue of the per-leaf param shardings: the 2D
        # (rows, cols) buffer shards its cols (= quant_block, a power
        # of two) over the model axes in parallel mode, or over the
        # data axes under sequential/FSDP (ZeRO-style) — one rule for
        # params and the FedOpt server state alike
        flat_sh = NamedSharding(
            mesh, P(None, maxes or None) if not seq_fed
            else P(None, daxes))
        st_sh["params"] = flat_sh
        if "server_opt" in state:
            st_sh["server_opt"] = {k: flat_sh
                                   for k in state["server_opt"]}

    batch = _batch_struct(cfg, (C, b), seq)
    batch["labels"] = jnp.zeros((C, b, seq), jnp.int32)
    if seq_fed:
        b_sh = jax.tree.map(
            lambda x: NamedSharding(
                mesh, P(None, daxes, *([None] * (x.ndim - 2)))), batch)
    else:
        b_sh = jax.tree.map(
            lambda x: NamedSharding(
                mesh, P(caxes, *([None] * (x.ndim - 1)))), batch)

    rng = jax.random.PRNGKey(0)
    args = (_sds(state), _sds(batch), _sds(rng))
    in_sh = (st_sh, b_sh, NamedSharding(mesh, P()))
    out_sh = (st_sh, None)
    meta = dict(arch=arch_id, shape="train_4k", entry="train_round",
                num_clients=C, per_client_batch=b, strategy=fed.strategy,
                seq=seq, cfg=cfg, fed=fed, packed_state=packed_state)
    return Bundle(engine.round, args, in_sh, out_sh, meta)


def _serve_cfg(arch_id: str, shape_name: str, reduced: bool,
               cfg_overrides: Optional[dict] = None) -> ModelConfig:
    cfg = _apply_overrides(configs.get_model_config(arch_id), cfg_overrides)
    if reduced:
        cfg = cfg.reduced(d_model=128)
    if shape_name == "long_500k" and "global" in cfg.block_pattern:
        cfg = dataclasses.replace(cfg, long_mode_swa_only=True)
    return cfg


def _serve_param_shardings(arch_id, cfg, mesh):
    # qwen3-moe's 470GB of bf16 experts exceed model-axis-only sharding ->
    # 2D weight sharding for serving. Everything else: pure TP.
    fsdp = data_axes(mesh) if arch_id == "qwen3-moe-235b-a22b" else None
    params = jax.eval_shape(lambda k: T.init_lm(k, cfg),
                            jax.random.PRNGKey(0))
    return params, S.param_shardings(cfg, mesh, params, fsdp_axes=fsdp)


def build_prefill(arch_id: str, mesh, *, reduced: bool = False,
                  cfg_overrides: Optional[dict] = None) -> Bundle:
    cfg = _serve_cfg(arch_id, "prefill_32k", reduced, cfg_overrides)
    shape = INPUT_SHAPES["prefill_32k"]
    B, seq = shape.global_batch, shape.seq_len
    if reduced:
        B, seq = 4, 64
    params, p_sh = _serve_param_shardings(arch_id, cfg, mesh)
    daxes = data_axes(mesh)
    batch = _batch_struct(cfg, (B,), seq)
    b_sh = jax.tree.map(
        lambda x: NamedSharding(mesh, P(daxes, *([None] * (x.ndim - 1)))),
        batch)

    def prefill(params, batch):
        logits, cache, _ = T.forward(params, cfg, batch, want_cache=True,
                                     remat=False)
        return logits, cache

    cache_struct = jax.eval_shape(
        lambda p, b: prefill(p, b)[1], params, batch)
    c_sh = S.cache_shardings(cfg, mesh, cache_struct, batch_axes=daxes)
    out_sh = (NamedSharding(mesh, P(daxes, None, "model")), c_sh)
    args = (_sds(params), _sds(batch))
    meta = dict(arch=arch_id, shape="prefill_32k", entry="serve_prefill",
                batch=B, seq=seq, cfg=cfg)
    return Bundle(prefill, args, (p_sh, b_sh), out_sh, meta)


def build_decode(arch_id: str, shape_name: str, mesh, *,
                 reduced: bool = False,
                 cfg_overrides: Optional[dict] = None) -> Bundle:
    cfg = _serve_cfg(arch_id, shape_name, reduced, cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    B, seq = shape.global_batch, shape.seq_len
    if reduced:
        B, seq = 4, 64
    params, p_sh = _serve_param_shardings(arch_id, cfg, mesh)
    daxes = data_axes(mesh)
    batch = _batch_struct(cfg, (B,), 1)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    batch_entry = daxes if (B % dsize == 0 and B >= dsize) else None
    b_sh = jax.tree.map(
        lambda x: NamedSharding(mesh,
                                P(batch_entry, *([None] * (x.ndim - 1)))),
        batch)
    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, seq))
    c_sh = S.cache_shardings(cfg, mesh, cache, batch_axes=daxes)
    pos = jnp.zeros((), jnp.int32)

    def step(params, batch, cache, pos):
        return T.decode_step(params, cfg, batch, cache, pos)

    logit_sh = NamedSharding(mesh, P(batch_entry, None, "model"))
    args = (_sds(params), _sds(batch), _sds(cache), _sds(pos))
    in_sh = (p_sh, b_sh, c_sh, NamedSharding(mesh, P()))
    out_sh = (logit_sh, c_sh)
    meta = dict(arch=arch_id, shape=shape_name, entry="serve_step",
                batch=B, cache_len=seq, cfg=cfg)
    return Bundle(step, args, in_sh, out_sh, meta)


def build(arch_id: str, shape_name: str, mesh, *, reduced: bool = False,
          **kw) -> Bundle:
    ok, reason = applicable(arch_id, shape_name)
    if not ok:
        raise ValueError(f"skip {arch_id} x {shape_name}: {reason}")
    kind = INPUT_SHAPES[shape_name].kind
    if kind == "train":
        return build_train(arch_id, mesh, reduced=reduced, **kw)
    cfg_overrides = kw.pop("cfg_overrides", None)
    if kind == "prefill":
        return build_prefill(arch_id, mesh, reduced=reduced,
                             cfg_overrides=cfg_overrides)
    return build_decode(arch_id, shape_name, mesh, reduced=reduced,
                        cfg_overrides=cfg_overrides)
