"""Training launcher: federated Fed-Sophia (or baselines) on any arch.

On real hardware this runs the full production mesh; on CPU it runs
reduced configs for end-to-end validation:

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --reduced --rounds 5
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro import configs, obs
from repro.checkpoint import ckpt
from repro.comm import round_bytes
from repro.comm import flat as cflat
from repro.configs.base import (AGGREGATORS, ATTACKS, LATENCY_PROFILES,
                                SCHED_DISCIPLINES, CommConfig, FedConfig,
                                ObsConfig, RobustConfig, SchedConfig)
from repro.core.fed import FedEngine
from repro.data import synthetic as syn
from repro.metrics import energy
from repro.models import transformer as T
from repro.robust import aggregators as robust_agg
from repro.robust import attacks as robust_attacks
from repro.sched import VirtualScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-iters", type=int, default=5)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--tau", type=int, default=5)
    ap.add_argument("--optimizer", default="fed_sophia")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced model dims (CPU-feasible)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="fused Sophia kernel (interpret mode on CPU)")
    # communication layer (repro.comm)
    ap.add_argument("--compressor", default="identity",
                    choices=("identity", "int8", "int4", "topk", "signsgd"),
                    help="uplink delta compressor")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients sampled per round")
    ap.add_argument("--topk-ratio", type=float, default=0.01)
    ap.add_argument("--error-feedback", default="auto",
                    choices=("auto", "on", "off"),
                    help="per-client EF residuals (auto: biased "
                         "compressors only)")
    ap.add_argument("--sign-majority", action="store_true",
                    help="signsgd: server-side majority vote")
    ap.add_argument("--downlink-compressor", default="identity",
                    choices=("identity", "int8", "int4", "topk", "signsgd"),
                    help="server broadcast compressor (delta vs each "
                         "client's last-received model, server-side EF)")
    ap.add_argument("--hessian-compressor", default="off",
                    choices=("off", "identity", "int8", "int4", "topk",
                             "signsgd"),
                    help="Sophia h-EMA uplink compressor (curvature "
                         "averaging; 'off' keeps curvature local)")
    ap.add_argument("--comm-pallas", action="store_true",
                    help="fused quantize/dequantize kernels (interpret on CPU)")
    # device residency of the engine state (docs/architecture.md
    # "Memory layout: the life of a round")
    ap.add_argument("--state-dtype", default="float32",
                    choices=("float32", "bfloat16"),
                    help="storage dtype of resident wire-layout state "
                         "(params between rounds, Sophia m/h, EF, "
                         "replicas); bfloat16 halves its HBM, compute "
                         "stays fp32")
    ap.add_argument("--moment-dtype", default="",
                    choices=("", "float32", "bfloat16",
                             "float8_e4m3fn", "float8_e5m2"),
                    help="per-buffer override of --state-dtype for the "
                         "Sophia first-moment stack (e4m3: more "
                         "mantissa; '' = follow --state-dtype)")
    ap.add_argument("--hessian-dtype", default="",
                    choices=("", "float32", "bfloat16",
                             "float8_e4m3fn", "float8_e5m2"),
                    help="per-buffer override of --state-dtype for the "
                         "hessian-EMA stack (e5m2: more range; "
                         "'' = follow --state-dtype)")
    ap.add_argument("--tree-state", action="store_true",
                    help="keep params as a pytree between rounds and "
                         "skip buffer donation (the pre-residency "
                         "engine; default: packed, donated rounds)")
    # virtual-time round scheduling (repro.sched)
    ap.add_argument("--schedule", default="sync",
                    choices=SCHED_DISCIPLINES,
                    help="round discipline: sync (today's engine), "
                         "semisync (FedBuff-style buffered rounds) or "
                         "async (per-arrival staleness-weighted apply)")
    ap.add_argument("--buffer-size", type=int, default=0,
                    help="semisync: arrivals aggregated per round "
                         "(0 = all in-flight participants)")
    ap.add_argument("--staleness-power", type=float, default=0.5,
                    help="arrival weight (1+staleness)^-p")
    ap.add_argument("--dispatch-chunk", type=int, default=0,
                    help="run dispatch groups larger than this as a "
                         "sequence of fixed-size chunks (one "
                         "compilation; 0 = whole group at once)")
    ap.add_argument("--latency-profile", default="uniform",
                    choices=LATENCY_PROFILES,
                    help="per-client latency model of the virtual clock")
    # adversarial fleet (repro.robust; docs/robustness.md)
    ap.add_argument("--aggregator", default="mean", choices=AGGREGATORS,
                    help="server-side combiner of client contributions "
                         "(degenerate parameterizations keep the mean "
                         "path bitwise)")
    ap.add_argument("--trim-fraction", type=float, default=0.0,
                    help="trimmed_mean: per-coordinate per-side trim "
                         "fraction of the arrival stack")
    ap.add_argument("--clip-norm", type=float, default=0.0,
                    help="norm_clip: max L2 norm per arrival (0 = off)")
    ap.add_argument("--attack", default="none", choices=ATTACKS,
                    help="byzantine wire attack applied to malicious "
                         "clients' packed uplink buffers")
    ap.add_argument("--attack-fraction", type=float, default=0.0,
                    help="fraction of clients byzantine")
    ap.add_argument("--attack-scale", type=float, default=10.0,
                    help="multiplier of the 'scale' attack")
    ap.add_argument("--label-noise-fraction", type=float, default=0.0,
                    help="fraction of clients training on corrupted "
                         "labels")
    ap.add_argument("--label-noise-rate", type=float, default=0.5,
                    help="per-sample corruption probability on "
                         "label-noise clients")
    ap.add_argument("--dropout-prob", type=float, default=0.0,
                    help="per-dispatch client dropout probability on "
                         "the virtual clock (scheduler disciplines)")
    ap.add_argument("--rejoin-delay-s", type=float, default=0.0,
                    help="extra virtual seconds before a dropped "
                         "client's update is delivered")
    # structured telemetry (repro.obs; docs/observability.md)
    ap.add_argument("--probes", action="store_true",
                    help="device-side Sophia health probes in the round "
                         "metrics (clip fraction, m/h norms, curvature "
                         "freshness; fed_sophia only)")
    ap.add_argument("--trace", action="store_true",
                    help="per-dispatch trace contexts on the virtual "
                         "clock (sched_dispatch records + trace_ids; "
                         "export with tools/obs_trace.py)")
    ap.add_argument("--obs-log", default="",
                    help="write schema-validated JSONL telemetry to this "
                         "path (+ a .manifest.json on exit)")
    ap.add_argument("--obs-flush-every", type=int, default=10,
                    help="rounds per device-metrics flush (host syncs "
                         "only at this boundary in obs runs)")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the run into "
                         "this directory (annotated round/kernel spans)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true",
                    help="restore params from --ckpt-dir first "
                         "(validates the checkpoint's wire-layout "
                         "headers against the current comm config)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_model_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(d_model=128)
    over = configs.get_fed_overrides(args.arch)
    ef = {"auto": "auto", "on": True, "off": False}[args.error_feedback]
    comm = CommConfig(compressor=args.compressor,
                      participation=args.participation,
                      topk_ratio=args.topk_ratio,
                      error_feedback=ef,
                      sign_majority=args.sign_majority,
                      downlink_compressor=args.downlink_compressor,
                      hessian_compressor=args.hessian_compressor,
                      state_dtype=args.state_dtype,
                      moment_dtype=args.moment_dtype,
                      hessian_dtype=args.hessian_dtype,
                      use_pallas=args.comm_pallas)
    sched = SchedConfig(discipline=args.schedule,
                        buffer_size=args.buffer_size,
                        staleness_power=args.staleness_power,
                        dispatch_chunk=args.dispatch_chunk,
                        latency_profile=args.latency_profile)
    robust = RobustConfig(aggregator=args.aggregator,
                          trim_fraction=args.trim_fraction,
                          clip_norm=args.clip_norm,
                          attack=args.attack,
                          attack_fraction=args.attack_fraction,
                          attack_scale=args.attack_scale,
                          label_noise_fraction=args.label_noise_fraction,
                          label_noise_rate=args.label_noise_rate,
                          dropout_prob=args.dropout_prob,
                          rejoin_delay_s=args.rejoin_delay_s,
                          seed=args.seed)
    fed = FedConfig(num_clients=args.clients, local_iters=args.local_iters,
                    optimizer=args.optimizer, lr=args.lr, tau=args.tau,
                    total_rounds=args.rounds, use_pallas=args.use_pallas,
                    schedule=over.get("schedule", "const"), comm=comm,
                    sched=sched, robust=robust,
                    obs=ObsConfig(probes=args.probes, trace=args.trace,
                                  flush_every=args.obs_flush_every))
    task = T.LMTask(cfg)
    engine = FedEngine(task, fed)
    key = jax.random.PRNGKey(args.seed)
    state = engine.init(key)
    if args.resume:
        manifest = ckpt.load_manifest(args.ckpt_dir)
        cflat.check_headers(manifest.get("extra", {}).get("wire", {}),
                            engine.wire_headers(state["params"]))
        # rebuild the wire-layout client state (downlink replicas, EF
        # residuals) around the restored model — broadcasting deltas
        # against the discarded random init would be garbage
        state = engine.restore_params(
            state, ckpt.restore(args.ckpt_dir, state["params"]))
        print(f"resumed params from {args.ckpt_dir} "
              f"(step {manifest['step']}, wire headers OK)")
    if not args.tree_state:
        # device residency: params stay packed in wire layout BETWEEN
        # rounds (pytrees materialize only at the eval/checkpoint
        # boundary below) and the jitted round donates the state, so
        # resident buffers update in place
        state = engine.pack_state(state)
    round_fn = engine.round_fn(donate=not args.tree_state)

    n_params = engine.num_params(state)
    # exact integers from the accounting model; the obs record schema
    # (repro.obs.schema) carries them downstream as exact int64 columns
    wire = round_bytes(comm, n_params, fed.num_clients)
    uplink_round = wire["uplink_bytes"]
    total_round = wire["total_bytes"]
    print(f"arch={cfg.name} params={n_params:,}"
          f" clients={fed.num_clients} J={fed.local_iters}"
          f" opt={fed.optimizer} compressor={comm.compressor}"
          f" downlink={comm.downlink_compressor}"
          f" hessian={comm.hessian_compressor}"
          f" participation={comm.participation:g}")
    # effective robust path of a full sync cohort (degenerate
    # parameterizations resolve to "mean" — today's path, bitwise)
    eff_agg = robust_agg.resolve(robust, wire["participants"])
    attack_on = robust_attacks.wire_attack_active(robust,
                                                 fed.num_clients)
    if eff_agg != "mean" or robust.adversarial:
        byz = [int(i) for i in
               robust_attacks.byzantine_mask(
                   robust, fed.num_clients).nonzero()[0]]
        print(f"adversarial fleet: aggregator={eff_agg} "
              f"attack={robust.attack if attack_on else 'none'} "
              f"byzantine={byz} "
              f"label_noise={robust.label_noise_fraction:g} "
              f"dropout={robust.dropout_prob:g}")
    print("per-round wire bytes: "
          + " ".join(f"{k}={wire[k]:,}" for k in
                     ("uplink_bytes", "downlink_bytes",
                      "hessian_uplink_bytes", "hessian_downlink_bytes",
                      "total_bytes")))
    # the canonical flat layout every resident state buffer lives in
    # (docs/architecture.md "Memory layout"); its header rides along in
    # the checkpoint manifest and is validated on --resume
    rt = engine.runtime_for(state["params"])
    residency = "tree" if args.tree_state else "packed+donated"
    dtypes = comm.state_dtype
    if comm.moment_dtype or comm.hessian_dtype:
        dtypes += (f" (m: {comm.moment_dtype or comm.state_dtype}, "
                   f"h: {comm.hessian_dtype or comm.state_dtype})")
    print(f"flat-resident state layout: {rt.spec.rows}x{rt.spec.cols} "
          f"{dtypes} ({rt.spec.total:,} coords + "
          f"{rt.spec.padded - rt.spec.total} pad), "
          f"between-round residency: {residency}")

    # per-round energy/carbon (paper Eq. 13-14 over the EXACT wire
    # bytes; repro.metrics.energy): static in the config, so priced once
    chan = energy.ChannelModel()
    comm_J = energy.tx_energy_joules(wire["total_bytes"], chan)
    # compute side: ~6*N FLOPs per trained token (fwd+bwd), J local
    # iterations per participant per round
    flops_iter = 6.0 * n_params * args.batch * args.seq
    compute_J = (energy.ComputeModel().energy_per_iteration(flops_iter)
                 * fed.local_iters * wire["participants"])
    round_J = comm_J + compute_J
    round_carbon = energy.footprint_kg_co2(round_J)

    recorder = None
    if args.obs_log:
        recorder = obs.RunRecorder(
            args.obs_log, ring_capacity=fed.obs.ring_capacity,
            meta={"arch": cfg.name, "params": n_params,
                  "clients": fed.num_clients,
                  "local_iters": fed.local_iters,
                  "optimizer": fed.optimizer,
                  "compressor": comm.compressor,
                  "schedule": args.schedule, "probes": fed.obs.probes,
                  "trace": fed.obs.trace, "residency": residency,
                  "state_dtype": comm.state_dtype,
                  "aggregator": robust.aggregator,
                  "attack": robust.attack})

    noisy = robust_attacks.label_noise_mask(robust, fed.num_clients)

    def make_batches(r):
        kb = jax.random.fold_in(key, 1000 + r)
        batches = syn.make_token_batch(kb, fed.num_clients, args.batch,
                                       args.seq, cfg.vocab_size)
        if noisy.any():
            # label-noise clients train on corrupted targets; the
            # corruption runs at data-build time (host numpy), so the
            # jitted round is untouched
            batches = dict(batches, labels=jnp.asarray(
                robust_attacks.corrupt_labels(robust, batches["labels"],
                                              noisy, cfg.vocab_size)))
        if cfg.embedding_inputs:
            ke = jax.random.fold_in(kb, 1)
            batches = {"embeds": jax.random.normal(
                ke, (fed.num_clients, args.batch, args.seq, cfg.d_model),
                dtype=T.param_dtype(cfg)), "labels": batches["labels"]}
        return batches

    spans = obs.SpanLog()

    def round_line(r, loss, lr, dt, row=None):
        clip = (f" clip={row['clip_fraction']:.3f}"
                if row and "clip_fraction" in row else "")
        return (f"round {r:3d} loss={loss:.4f} lr={lr:.2e} "
                f"uplink={uplink_round / 2**20:.2f}MiB "
                f"total={total_round / 2**20:.2f}MiB "
                f"(cum {(r + 1) * total_round / 2**20:.2f}MiB)"
                f"{clip} ({dt:.1f}s)")

    def emit_round(r, row, wall_s):
        rec = {"record": "round", "round": r, "loss": row["loss"],
               "lr": row["lr"], "participants": wire["participants"],
               "cum_total_bytes": (r + 1) * total_round,
               "energy_J": round_J, "comm_J": comm_J,
               "compute_J": compute_J, "carbon_kg": round_carbon,
               "wall_s": wall_s}
        for k in ("uplink_bytes", "downlink_bytes",
                  "hessian_uplink_bytes", "hessian_downlink_bytes",
                  "total_bytes"):
            rec[k] = wire[k]
        for k in obs.PROBE_METRICS:
            if k in row:
                rec[k] = row[k]
        # robust context rides along only when the run departs from
        # the default mean/no-attack path (schema: optional fields)
        if eff_agg != "mean":
            rec["aggregator"] = eff_agg
        if attack_on:
            rec["attack"] = robust.attack
        recorder.emit(rec)

    with obs.profile_trace(args.profile_dir):
        if args.schedule == "sync" and recorder is None:
            # the existing synchronous loop, bit-identical to earlier
            # builds (the per-round host sync is the loss print itself)
            for r in range(args.rounds):
                t0 = time.time()
                with spans.span("round"):
                    state, metrics = round_fn(state, make_batches(r),
                                              jax.random.fold_in(key, r))
                print(round_line(r, float(metrics["loss"]),
                                 float(metrics["lr"]),
                                 time.time() - t0), flush=True)
        elif args.schedule == "sync":
            # obs loop: round metrics (incl. the in-jit Sophia health
            # probes) accumulate in a device-side buffer; the host
            # syncs, records and prints only at the flush boundary —
            # strictly FEWER host syncs than the plain loop
            acc = obs.MetricsAccumulator(fed.obs.flush_every)
            pending = []
            t0 = time.time()
            for r in range(args.rounds):
                with spans.span("round"):
                    state, metrics = round_fn(state, make_batches(r),
                                              jax.random.fold_in(key, r))
                acc.add(metrics)
                pending.append(r)
                if len(acc) == fed.obs.flush_every or r == args.rounds - 1:
                    with spans.span("flush"):
                        rows = acc.flush()
                    dt = (time.time() - t0) / len(pending)
                    for rr, row in zip(pending, rows):
                        emit_round(rr, row, dt)
                        print(round_line(rr, row["loss"], row["lr"], dt,
                                         row), flush=True)
                    pending = []
                    t0 = time.time()
        else:
            # virtual-time event loop (repro.sched): --rounds counts
            # aggregation events; the printed time is SIMULATED seconds.
            # The apply jit donates the state unless --tree-state.
            scheduler = VirtualScheduler(engine, make_batches,
                                         donate=not args.tree_state)
            state, trace = scheduler.run(state, args.rounds, key)
            for ev in trace.events:
                stale = max(ev.staleness) if ev.staleness else 0
                clip = (f" clip={ev.probes['clip_fraction']:.3f}"
                        if ev.probes else "")
                print(f"event {ev.version:3d} t={ev.time:9.2f}s "
                      f"loss={ev.loss:.4f} clients={list(ev.clients)} "
                      f"max_stale={stale} "
                      f"cum={ev.cum_bytes / 2**20:.2f}MiB{clip}",
                      flush=True)
            print(f"{args.schedule}: {len(trace.events)} events, "
                  f"simulated {trace.final_time:.2f}s, "
                  f"{trace.total_bytes / 2**20:.2f}MiB on the wire")
            if recorder is not None:
                # structured SchedEvent records (exact per-stream int64
                # byte counters, staleness histogram, per-event
                # energy), then the scheduler's own span timers
                recorder.emit_all(trace.to_records(channel=chan))
                recorder.emit_all(scheduler.spans.records())
    if recorder is not None:
        recorder.emit_all(spans.records())
        recorder.close()
        print(f"wrote {recorder.counts} obs records to {args.obs_log} "
              f"(+ {recorder.manifest_path})")
    if args.ckpt_dir:
        extra = {"arch": args.arch,
                 "wire": engine.wire_headers(state["params"])}
        if engine.params_packed(state["params"]):
            # checkpoint boundary shim: the on-disk format is the
            # pytree regardless of the between-round residency
            ckpt.save_packed(args.ckpt_dir, state["params"], rt.spec,
                             step=args.rounds, extra=extra)
        else:
            ckpt.save(args.ckpt_dir, state["params"], step=args.rounds,
                      extra=extra)
        print(f"saved checkpoint to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
