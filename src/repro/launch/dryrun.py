import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))
# ^ MUST precede every other import: jax locks device count on first init.

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro import configs
from repro.configs.base import INPUT_SHAPES
from repro.launch import api
from repro.launch.mesh import make_production_mesh, make_small_mesh
from repro.launch.hlo_cost import HloCost
from repro.launch.roofline import (collective_bytes, count_params,
                                   model_flops, roofline_terms)


def _mem_dict(mem):
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_donation_check(arch: str, *, multi_pod: bool = False,
                       local_iters: int = 2,
                       out_dir: str = "", tag: str = "") -> dict:
    """GSPMD donation-aliasing dryrun: lower+compile the PACKED-resident
    train round on a simulated multi-host mesh with the state donated,
    and verify the donation SURVIVES PARTITIONING — every per-device
    shard of the resident (rows, cols) wire buffer and of the
    (C, rows, cols) client stacks (Sophia m/h, EF, replicas) must be
    aliased in place by XLA (state_copy_bytes == 0), the multi-host
    analogue of the single-device residency gate in
    `benchmarks.run.fig_engine`.  Reduced dims always: this is a
    partitioning property, not a capacity test."""
    import numpy as np
    mesh = make_small_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "check": "donation-aliasing",
           "mesh": "small" + ("2pod" if multi_pod else "1pod"),
           "mesh_shape": {k: int(v) for k, v in mesh.shape.items()}}
    try:
        bundle = api.build_train(arch, mesh, reduced=True,
                                 local_iters=local_iters,
                                 packed_state=True)
        with mesh:
            jitted = jax.jit(bundle.fn,
                             in_shardings=bundle.in_shardings,
                             out_shardings=bundle.out_shardings,
                             donate_argnums=(0,))
            compiled = jitted.lower(*bundle.args).compile()
            mem = _mem_dict(compiled.memory_analysis())
        # per-device resident footprint: each state leaf's shard shape
        # under its declared sharding (replicated leaves count whole)
        state_leaves = jax.tree.leaves(bundle.args[0])
        sh_leaves = jax.tree.leaves(bundle.in_shardings[0])
        per_dev = sum(
            int(np.prod(s.shard_shape(l.shape))) * l.dtype.itemsize
            for l, s in zip(state_leaves, sh_leaves))
        aliased = mem.get("alias_size_in_bytes", 0)
        copy_b = max(0, per_dev - aliased)
        rec.update(
            status="ok" if copy_b == 0 else "error",
            memory=mem,
            resident_shard_bytes_per_dev=per_dev,
            state_copy_bytes=copy_b)
        if copy_b:
            rec["error"] = (
                f"donation lost under partitioning: {copy_b} of "
                f"{per_dev} resident bytes/device not aliased in place")
    except Exception as e:                            # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _save(rec, out_dir, arch, "donation", rec["mesh"], "fed_sophia", tag)
    return rec


def parse_overrides(s: str) -> dict:
    """'k=v,k2=v2' -> {k: v} (values stay strings; api coerces)."""
    out = {}
    for kv in (s or "").split(","):
        if "=" in kv:
            k, _, v = kv.partition("=")
            out[k.strip()] = v.strip()
    return out


def run_one(arch: str, shape: str, *, multi_pod: bool = False,
            reduced: bool = False, small_mesh: bool = False,
            optimizer: str = "fed_sophia", local_iters: int = 10,
            out_dir: str = "experiments/dryrun", tag: str = "",
            cfg_overrides: dict | None = None,
            fed_overrides: dict | None = None,
            fsdp_gather: bool = True) -> dict:
    mesh_name = ("small" if small_mesh else "prod") + \
        ("2pod" if multi_pod else "1pod")
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "optimizer": optimizer, "tag": tag}
    ok, reason = api.applicable(arch, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        _save(rec, out_dir, arch, shape, mesh_name, optimizer, tag)
        return rec

    mesh = (make_small_mesh(multi_pod=multi_pod) if small_mesh
            else make_production_mesh(multi_pod=multi_pod))
    rec["mesh_shape"] = {k: int(v) for k, v in mesh.shape.items()}
    t0 = time.time()
    try:
        kw = {"cfg_overrides": cfg_overrides}
        if INPUT_SHAPES[shape].kind == "train":
            kw.update(optimizer=optimizer, local_iters=local_iters,
                      fsdp_gather=fsdp_gather,
                      fed_overrides=fed_overrides)
        bundle = api.build(arch, shape, mesh, reduced=reduced, **kw)
        with mesh:
            jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                             out_shardings=bundle.out_shardings)
            lowered = jitted.lower(*bundle.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = _mem_dict(compiled.memory_analysis())
            cost = dict(compiled.cost_analysis() or {})
            hlo = compiled.as_text()
        # loop-aware cost model (XLA's counts while bodies only once)
        hc = HloCost(hlo).summary()
        flops = float(hc["flops"])
        byts = float(hc["bytes"])
        coll = dict(hc["collectives"])
        coll["total"] = hc["collective_total"]
        terms = roofline_terms(flops, byts, coll["total"])
        cfg = bundle.meta["cfg"]
        nchips = 1
        for v in mesh.shape.values():
            nchips *= int(v)
        mflops = model_flops(cfg, shape, local_iters=local_iters) \
            if not reduced else 0.0
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            entry=bundle.meta["entry"],
            memory=mem,
            hlo_flops_per_dev=flops,
            hlo_bytes_per_dev=byts,
            xla_cost_analysis={k: float(v) for k, v in cost.items()
                               if isinstance(v, (int, float))
                               and k in ("flops", "bytes accessed",
                                         "transcendentals")},
            collective_bytes=coll,
            roofline=terms,
            params=count_params(cfg),
            model_flops_total=mflops,
            useful_flops_ratio=(mflops / (flops * nchips)
                                if flops and mflops else None),
            hlo_collective_ops={k: v for k, v in coll.items()
                                if k != "total"},
            bytes_by_opcode=hc.get("bytes_by_opcode", {}),
            flops_by_opcode=hc.get("flops_by_opcode", {}),
        )
    except Exception as e:                            # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _save(rec, out_dir, arch, shape, mesh_name, optimizer, tag)
    return rec


def _save(rec, out_dir, arch, shape, mesh_name, optimizer, tag):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    fn = f"{arch}_{shape}_{mesh_name}"
    if optimizer != "fed_sophia":
        fn += f"_{optimizer}"
    if tag:
        fn += f"_{tag}"
    with open(os.path.join(out_dir, fn + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run 1-pod and 2-pod for each combo")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced model dims (CI smoke)")
    ap.add_argument("--small-mesh", action="store_true",
                    help="8-device mesh (CI smoke)")
    ap.add_argument("--optimizer", default="fed_sophia")
    ap.add_argument("--local-iters", type=int, default=10)
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--overrides", default="",
                    help="ModelConfig overrides, e.g. slstm_unroll=16")
    ap.add_argument("--fed-overrides", default="",
                    help="FedConfig overrides, e.g. hessian_every_unit=round")
    ap.add_argument("--no-fsdp-gather", action="store_true",
                    help="§Perf baseline: skip the explicit FSDP gather "
                         "constraint in sequential-strategy training")
    ap.add_argument("--check-donation", action="store_true",
                    help="GSPMD donation-aliasing dryrun: compile the "
                         "packed-resident train round with the state "
                         "donated and assert every resident shard is "
                         "aliased in place under partitioning")
    args = ap.parse_args()
    overrides = parse_overrides(args.overrides)

    archs = configs.ARCH_IDS if args.arch == "all" else [args.arch]
    if args.check_donation:
        failures = 0
        for arch in archs:
            rec = run_donation_check(arch, multi_pod=args.multi_pod,
                                     local_iters=args.local_iters,
                                     out_dir=args.out_dir, tag=args.tag)
            status = rec["status"]
            line = f"[{status:7s}] {arch:24s} donation {rec['mesh']}"
            if status == "ok":
                line += (f" resident/dev="
                         f"{rec['resident_shard_bytes_per_dev']}B"
                         f" state_copy_B={rec['state_copy_bytes']}")
            else:
                line += f" {rec['error'][:160]}"
                failures += 1
            print(line, flush=True)
        raise SystemExit(1 if failures else 0)
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    pods = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                rec = run_one(arch, shape, multi_pod=mp,
                              reduced=args.reduced,
                              small_mesh=args.small_mesh,
                              optimizer=args.optimizer,
                              local_iters=args.local_iters,
                              out_dir=args.out_dir, tag=args.tag,
                              cfg_overrides=overrides,
                              fed_overrides=parse_overrides(
                                  args.fed_overrides),
                              fsdp_gather=not args.no_fsdp_gather)
                status = rec["status"]
                line = f"[{status:7s}] {arch:24s} {shape:12s} {rec['mesh']}"
                if status == "ok":
                    r = rec["roofline"]
                    line += (f" compile={rec['compile_s']:.1f}s"
                             f" flops/dev={rec['hlo_flops_per_dev']:.3g}"
                             f" coll={rec['collective_bytes']['total']:.3g}B"
                             f" bottleneck={r['bottleneck']}")
                elif status == "skipped":
                    line += f" ({rec['reason']})"
                else:
                    line += f" {rec['error'][:160]}"
                    failures += 1
                print(line, flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
