"""Obs sinks: JSONL file, bounded in-memory ring, run recorder.

`RunRecorder` is the one object launchers talk to: it validates every
record against the schema (`repro.obs.schema.validate_record` — a bad
record fails at emit time, next to the bug), writes it to the JSONL
log and the ring, and on `close` writes a CI-consumable run manifest
(``<log>.manifest.json``) with the schema fingerprint and per-type
record counts — what `tools/obs_report.py --validate` and the
``make obs-smoke`` CI step consume.
"""
from __future__ import annotations

import json
import os
from collections import deque
from typing import Any, Dict, List, Optional

from repro.obs import schema


class JsonlSink:
    """Append-only JSONL file; one record per line, sorted keys (the
    byte stream is deterministic in the record sequence)."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w")
        self.count = 0

    def write(self, rec: Dict[str, Any]) -> None:
        self._f.write(json.dumps(rec, sort_keys=True) + "\n")
        self.count += 1

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


class RingSink:
    """Bounded in-memory record ring (most recent ``capacity``)."""

    def __init__(self, capacity: int = 1024):
        self._ring: deque = deque(maxlen=int(capacity))

    def write(self, rec: Dict[str, Any]) -> None:
        self._ring.append(rec)

    def records(self) -> List[Dict[str, Any]]:
        return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)


class RunRecorder:
    """Validating fan-out recorder for one run.

    Emits the ``manifest`` record as the log's first line (schema
    version + fingerprint, so a reader can reject a drifted log before
    parsing anything else), then every record the run produces.
    """

    def __init__(self, path: Optional[str] = None,
                 ring_capacity: int = 1024,
                 meta: Optional[Dict[str, Any]] = None,
                 validate: bool = True):
        self.jsonl = JsonlSink(path) if path else None
        self.ring = RingSink(ring_capacity)
        self.validate = validate
        self.meta = dict(meta or {})
        self.counts: Dict[str, int] = {}
        self._closed = False
        head = {"record": "manifest",
                "schema_version": schema.SCHEMA_VERSION,
                "schema_sha256": schema.fingerprint()}
        if self.meta:
            head["meta"] = self.meta
        self.emit(head)

    def emit(self, rec: Dict[str, Any]) -> Dict[str, Any]:
        if self._closed:
            raise ValueError("recorder is closed")
        if self.validate:
            schema.validate_record(rec)
        self.counts[rec["record"]] = self.counts.get(rec["record"], 0) + 1
        self.ring.write(rec)
        if self.jsonl:
            self.jsonl.write(rec)
        return rec

    def emit_all(self, recs) -> None:
        for r in recs:
            self.emit(r)

    @property
    def manifest_path(self) -> Optional[str]:
        return self.jsonl.path + ".manifest.json" if self.jsonl else None

    def close(self) -> Optional[str]:
        """Close the log and write the run manifest; returns its path
        (None for ring-only recorders)."""
        if self._closed:
            return self.manifest_path
        self._closed = True
        if self.jsonl is None:
            return None
        self.jsonl.close()
        manifest = {"schema_version": schema.SCHEMA_VERSION,
                    "schema_sha256": schema.fingerprint(),
                    "log": os.path.basename(self.jsonl.path),
                    "records": dict(sorted(self.counts.items())),
                    "meta": self.meta}
        with open(self.manifest_path, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
            f.write("\n")
        return self.manifest_path
