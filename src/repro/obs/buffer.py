"""Packed device-side metrics buffer (docs/observability.md).

The round metrics dict (`FedEngine.round`) is a handful of float32
device scalars per round.  Calling ``float(...)`` on them every round
forces a host sync per metric per round; `MetricsAccumulator` instead
stores each round's scalars into one preallocated (capacity, N)
device buffer — enqueue-only device work, nothing is fetched — and
transfers the whole window in ONE device->host copy at `flush`, the
existing eval/checkpoint boundary.  Probed obs runs therefore sync
the host strictly less often than the plain print loop, not more.

The donation contract is untouched: the accumulator only holds the
metrics OUTPUT of the round jit (fresh buffers, never the donated
state argument).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


class MetricsAccumulator:
    """Accumulates scalar-metric dicts on device; flushes as floats.

    The metric name set is frozen by the first `add` (every round
    emits the same dict shape); rows beyond ``capacity`` without a
    flush are a caller bug and raise.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._names: tuple = ()
        self._buf = None
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def add(self, metrics: Dict[str, jnp.ndarray]) -> None:
        """Store one round's scalar metrics — device-side only (the
        stack + row store dispatch asynchronously; no host sync)."""
        if self._buf is None:
            self._names = tuple(sorted(metrics))
            self._buf = jnp.zeros((self.capacity, len(self._names)),
                                  jnp.float32)
        elif tuple(sorted(metrics)) != self._names:
            raise ValueError(
                f"metric names changed mid-run: "
                f"{sorted(metrics)} != {list(self._names)}")
        if self._n >= self.capacity:
            raise ValueError(
                f"metrics buffer full ({self.capacity} rows) — flush() "
                f"at the eval/checkpoint boundary first")
        row = jnp.stack([jnp.asarray(metrics[k], jnp.float32).reshape(())
                         for k in self._names])
        self._buf = self._buf.at[self._n].set(row)
        self._n += 1

    def flush(self) -> List[Dict[str, float]]:
        """ONE device->host transfer: the buffered rows as plain-float
        dicts, in insertion order.  Resets the buffer."""
        if not self._n:
            return []
        host = np.asarray(jax.device_get(self._buf[:self._n]))
        self._n = 0
        return [dict(zip(self._names, map(float, row))) for row in host]
