"""Chrome Trace Event / Perfetto export of an obs record stream.

Renders a scheduler run — ``sched_dispatch`` trace contexts
(``ObsConfig.trace``), ``sched_event`` aggregations, ``span`` timers
and probe scalars — as one Chrome Trace Event JSON object
(``chrome://tracing`` legacy format, loadable in Perfetto's UI):

* **pid 1 — clients**: one thread lane per client; each dispatch's
  trace context becomes three ``X`` slices (``downlink`` ->
  ``compute`` -> ``uplink``) sized by `repro.sched.latency
  .dispatch_legs` and carrying the exact per-stream byte counters in
  ``args``.  The uplink slice is anchored to end at the authoritative
  ``arrival_s`` (the leg decomposition may differ from the lumped
  clock arithmetic in the last ulps).
* **pid 2 — server**: one ``apply`` slice per aggregation event,
  spanning from the earliest folded arrival (via ``trace_ids``) to
  the event's apply time — buffering/staleness pathologies are the
  visible gap.  Without trace contexts the event degrades to an
  instant marker.
* **pid 3 — counters**: ``C`` tracks for loss and the Sophia health
  probes (``clip_fraction``, ``h_staleness``) per event.
* **pid 4 — host**: ``span`` records on the *wall* clock (their own
  process, so the virtual-time lanes stay uncontaminated).

Timestamps are virtual seconds scaled to microseconds and rounded to
1e-3 us, so the export is byte-deterministic (golden-pinned by
tests/test_obs_tools.py).  Pure stdlib — no jax imports — so the
tools (tools/obs_trace.py) stay fast to start.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Tuple

#: displayed process lanes, in pid order
PROCESS_NAMES = {1: "clients", 2: "server", 3: "counters", 4: "host"}

#: probe scalars rendered as counter tracks (subset of
#: repro.obs.probes.PROBE_METRICS, chosen for at-a-glance pathology:
#: Eq. 11 clip saturation and curvature staleness)
COUNTER_PROBES = ("clip_fraction", "h_staleness")


def _us(seconds: float) -> float:
    """Virtual seconds -> trace microseconds, quantized to 1e-3 us so
    float formatting is stable across platforms."""
    return round(seconds * 1e6, 3)


def _meta(pid: int, name: str, tid: int = 0,
          thread: str = "") -> List[Dict[str, Any]]:
    evs = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "ts": 0, "args": {"name": name}}]
    if thread:
        evs.append({"name": "thread_name", "ph": "M", "pid": pid,
                    "tid": tid, "ts": 0, "args": {"name": thread}})
    return evs


def chrome_trace(records: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Export obs records as a Chrome Trace Event JSON object.

    Accepts any record mix (a whole run log); non-scheduler records
    are ignored.  Deterministic: equal record streams produce
    byte-equal ``json.dumps(..., sort_keys=True)`` output.
    """
    records = list(records)
    dispatches = [r for r in records
                  if r.get("record") == "sched_dispatch"]
    events = [r for r in records if r.get("record") == "sched_event"]
    spans = [r for r in records if r.get("record") == "span"]
    arrival_by_tid = {d["trace_id"]: d["arrival_s"] for d in dispatches}

    out: List[Dict[str, Any]] = []
    meta: List[Dict[str, Any]] = []
    used_pids = set()

    # ---- client lanes: downlink -> compute -> uplink per dispatch
    for d in dispatches:
        used_pids.add(1)
        tid = d["client"]
        t0 = d["time_s"]
        legs = (
            ("downlink", t0, d["downlink_s"],
             {"bytes": d.get("downlink_bytes", 0)
              + d.get("hessian_downlink_bytes", 0)}),
            ("compute", t0 + d["downlink_s"], d["compute_s"], {}),
            ("uplink", d["arrival_s"] - d["uplink_s"], d["uplink_s"],
             {"bytes": d.get("uplink_bytes", 0)
              + d.get("hessian_uplink_bytes", 0)}),
        )
        for name, start, dur, extra in legs:
            out.append({
                "name": name, "ph": "X", "pid": 1, "tid": tid,
                "ts": _us(start), "dur": max(_us(dur), 0.0),
                "args": {"trace_id": d["trace_id"],
                         "version": d["version"], **extra}})
    for tid in sorted({d["client"] for d in dispatches}):
        meta.append({"name": "thread_name", "ph": "M", "pid": 1,
                     "tid": tid, "ts": 0,
                     "args": {"name": f"client {tid}"}})

    # ---- server lane: one apply slice (or instant) per event
    for ev in events:
        used_pids.add(2)
        args = {"version": ev["version"], "kind": ev["kind"],
                "clients": list(ev["clients"]),
                "staleness": list(ev["staleness"]),
                "loss": ev["loss"],
                "cum_total_bytes": ev["cum_total_bytes"]}
        tids = ev.get("trace_ids") or ()
        arrivals = [arrival_by_tid[t] for t in tids
                    if t in arrival_by_tid]
        if arrivals:
            start = min(arrivals)
            out.append({"name": "apply", "ph": "X", "pid": 2, "tid": 0,
                        "ts": _us(start),
                        "dur": max(_us(ev["time_s"] - start), 0.0),
                        "args": {**args, "trace_ids": list(tids)}})
        else:
            out.append({"name": "apply", "ph": "i", "pid": 2, "tid": 0,
                        "ts": _us(ev["time_s"]), "s": "t",
                        "args": args})

    # ---- counter tracks: loss + selected probes per event
    for ev in events:
        series = [("loss", ev["loss"])]
        series += [(k, ev[k]) for k in COUNTER_PROBES if k in ev]
        for name, value in series:
            used_pids.add(3)
            out.append({"name": name, "ph": "C", "pid": 3, "tid": 0,
                        "ts": _us(ev["time_s"]),
                        "args": {"value": value}})

    # ---- host spans (wall clock, own process)
    for s in spans:
        used_pids.add(4)
        args = {}
        if "virtual_s" in s:
            args["virtual_s"] = s["virtual_s"]
        if "trace_id" in s:
            args["trace_id"] = s["trace_id"]
        out.append({"name": s["name"], "ph": "X", "pid": 4, "tid": 0,
                    "ts": _us(s["t_wall_s"]),
                    "dur": max(_us(s["wall_s"]), 0.0), "args": args})

    for pid in sorted(used_pids):
        meta += _meta(pid, PROCESS_NAMES[pid])

    # metadata first, then a total order on (ts, pid, tid, name) so
    # equal inputs serialize byte-identically AND every lane's slices
    # appear in non-decreasing ts order (what the validator checks)
    meta.sort(key=lambda e: (e["pid"], e["tid"], e["name"]))
    out.sort(key=lambda e: (e["ts"], e["pid"], e["tid"], e["name"]))
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


_REQUIRED = ("name", "ph", "pid", "tid", "ts")


def validate_chrome_trace(trace: Any) -> List[str]:
    """Structural validation of a `chrome_trace` export; returns a
    list of human-readable errors (empty = valid).  Checked: the
    top-level shape, per-event required keys, non-negative ``dur`` on
    complete slices, and non-decreasing ``ts`` within every
    ``(pid, tid)`` lane — the contract `make obs-trace-smoke` gates.
    """
    errors: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["not a Chrome trace: missing top-level 'traceEvents'"]
    evs = trace["traceEvents"]
    if not isinstance(evs, list) or not evs:
        return ["'traceEvents' must be a non-empty list"]
    last_ts: Dict[Tuple[int, int], float] = {}
    for n, e in enumerate(evs):
        if not isinstance(e, dict):
            errors.append(f"event {n}: not an object")
            continue
        missing = [k for k in _REQUIRED if k not in e]
        if missing:
            errors.append(f"event {n}: missing keys {missing}")
            continue
        ph = e["ph"]
        if ph == "X":
            if "dur" not in e:
                errors.append(f"event {n}: 'X' slice without dur")
            elif e["dur"] < 0:
                errors.append(f"event {n}: negative dur {e['dur']}")
        if ph == "M":
            continue                       # metadata carries ts=0
        lane = (e["pid"], e["tid"])
        if e["ts"] < last_ts.get(lane, float("-inf")):
            errors.append(
                f"event {n}: ts {e['ts']} goes backwards in lane "
                f"pid={lane[0]} tid={lane[1]}")
        last_ts[lane] = e["ts"]
    return errors
