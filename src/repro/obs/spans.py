"""Host-side span timers and `jax.profiler` trace hooks.

`SpanLog` times named host-side phases (pack/dispatch/apply/encode/
round/flush) and emits them as schema ``span`` records; a span opened
from the virtual-time scheduler carries the scheduler's clock in
``virtual_s``, correlating host wall-time with simulated time.  Every
span also enters a `jax.profiler.TraceAnnotation`, so when an opt-in
trace is active (``--profile-dir``) the same phases appear as
annotated regions in the profiler timeline — one instrumentation
point, two views.

`profile_trace` is the opt-in trace context: a no-op unless a
directory is given, and degrades to a warning (never a crash) when the
installed jax cannot start a trace on this backend.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import List, Optional

import jax


def annotate(name: str):
    """Profiler annotation for a host-side region (context manager);
    active only while a trace is being captured, ~free otherwise."""
    return jax.profiler.TraceAnnotation(name)


class SpanLog:
    """Collects ``span`` records; wall-clock zero is construction."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self._spans: List[dict] = []

    @contextmanager
    def span(self, name: str, virtual_s: Optional[float] = None,
             trace_id: Optional[int] = None):
        start = time.perf_counter()
        try:
            with annotate(name):
                yield
        finally:
            rec = {"record": "span", "name": name,
                   "t_wall_s": start - self._t0,
                   "wall_s": time.perf_counter() - start}
            if virtual_s is not None:
                rec["virtual_s"] = float(virtual_s)
            if trace_id is not None:
                rec["trace_id"] = int(trace_id)
            self._spans.append(rec)

    def records(self) -> List[dict]:
        return list(self._spans)


class profile_trace:
    """``with profile_trace(dir):`` captures a `jax.profiler` trace
    into ``dir`` (view with TensorBoard / Perfetto); a no-op when
    ``dir`` is empty."""

    def __init__(self, directory: str):
        self.directory = directory
        self._active = False

    def __enter__(self):
        if self.directory:
            try:
                jax.profiler.start_trace(self.directory)
                self._active = True
            except Exception as e:      # backend without profiler support
                print(f"profiler trace unavailable ({e}); "
                      f"continuing without", flush=True)
        return self

    def __exit__(self, *exc):
        if self._active:
            jax.profiler.stop_trace()
            self._active = False
            print(f"wrote profiler trace to {self.directory}",
                  flush=True)
        return False
