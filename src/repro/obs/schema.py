"""The versioned obs record schema (docs/observability.md).

Every telemetry record is one flat JSON object with a ``record`` type
tag.  This module is the single source of truth for what may appear in
one: the metric registry (name -> dtype/unit/description) and the
per-record-type field sets.  `validate_record` enforces both, plus the
dtype contracts — byte counters are EXACT int64 values (Python ints,
never floats), so counts stay exact far beyond the 2^24 mantissa limit
of the engine's in-jit float32 metric mirrors.

Versioning: bump `SCHEMA_VERSION` on any breaking change (removed or
retyped field).  Purely additive changes keep the version but still
change `fingerprint()` — the golden test (tests/test_obs.py, fixture
tests/golden/obs_schema.json) freezes the full canonical schema dump,
so any edit here is a deliberate, reviewed event:

    PYTHONPATH=src python tests/test_obs.py --regen

`tools/check_docs.py` regex-parses the ``Metric("name", ...)``
literals below (never imports this package), which is why each metric
is declared on its own line with a literal first argument — keep it
that way.
"""
from __future__ import annotations

import hashlib
import json
from typing import Any, Dict, NamedTuple, Tuple

SCHEMA_VERSION = 2

#: schema versions this checkout can still LOAD.  v1 logs lack the
#: trace context (``trace_id`` / ``sched_dispatch``) and the
#: ``serve`` record type but every v1 field survives unchanged, so
#: readers (tools/obs_report.py, tools/obs_diff.py) accept them; the
#: manifest fingerprint is only enforced on current-version logs.
SUPPORTED_SCHEMA_VERSIONS = (1, 2)

#: int64 range of the exact byte/count columns
_I64_MIN, _I64_MAX = -(2 ** 63), 2 ** 63 - 1


class ObsSchemaError(ValueError):
    """A record violated the obs schema."""


class Metric(NamedTuple):
    name: str
    dtype: str        # int64 | float64 | str | list[int] | list[float]
    #                   | hist | obj
    unit: str
    description: str


def _registry(*metrics: Metric) -> Dict[str, Metric]:
    out: Dict[str, Metric] = {}
    for m in metrics:
        if m.name in out:
            raise ValueError(f"duplicate metric {m.name!r}")
        out[m.name] = m
    return out


METRICS: Dict[str, Metric] = _registry(
    # ---- record framing
    Metric("record", "str", "", "record type tag"),
    Metric("schema_version", "int64", "",
           "obs schema version the log was written under"),
    Metric("schema_sha256", "str", "",
           "fingerprint() of the writing schema (drift detector)"),
    Metric("meta", "obj", "",
           "free-form run metadata (arch, config, host)"),
    # ---- training round
    Metric("round", "int64", "rounds", "0-based communication round"),
    Metric("loss", "float64", "nats",
           "mean local-training loss of the round's participants"),
    Metric("eval_loss", "float64", "nats",
           "held-out eval loss (sampled at the eval cadence)"),
    Metric("lr", "float64", "",
           "server learning rate at this round"),
    Metric("participants", "int64", "clients",
           "participants trained this round/event"),
    Metric("wall_s", "float64", "s",
           "host wall-clock per round (averaged within a flush window)"),
    # ---- exact per-stream wire bytes (accounting model, never the
    # ---- in-jit float32 mirrors)
    Metric("uplink_bytes", "int64", "bytes",
           "model-delta uplink payloads, all participants, this round"),
    Metric("downlink_bytes", "int64", "bytes",
           "per-client broadcast payloads, this round"),
    Metric("hessian_uplink_bytes", "int64", "bytes",
           "Sophia h-EMA uplink payloads, this round"),
    Metric("hessian_downlink_bytes", "int64", "bytes",
           "common averaged-curvature broadcast, this round"),
    Metric("total_bytes", "int64", "bytes",
           "all streams, this round"),
    Metric("cum_total_bytes", "int64", "bytes",
           "all streams, cumulative since round 0"),
    Metric("cum_uplink_bytes", "int64", "bytes",
           "cumulative uplink payload bytes"),
    Metric("cum_downlink_bytes", "int64", "bytes",
           "cumulative downlink payload bytes"),
    Metric("cum_hessian_uplink_bytes", "int64", "bytes",
           "cumulative hessian uplink payload bytes"),
    Metric("cum_hessian_downlink_bytes", "int64", "bytes",
           "cumulative hessian broadcast payload bytes"),
    # ---- energy / carbon (paper Eq. 13-14 channel model over the
    # ---- exact byte counts; repro.metrics.energy)
    Metric("energy_J", "float64", "J",
           "total (compute + transmission) energy of this round/event"),
    Metric("comm_J", "float64", "J",
           "transmission energy at the Shannon rate, exact wire bytes"),
    Metric("compute_J", "float64", "J",
           "local-training compute energy"),
    Metric("carbon_kg", "float64", "kg",
           "CO2 footprint of energy_J at the grid intensity"),
    # ---- Sophia health probes (repro.obs.probes; computed in-jit)
    Metric("clip_fraction", "float64", "",
           "fraction of coordinates at the +-rho bound of the Eq. 11 "
           "clipped preconditioned step, mean over participants"),
    Metric("m_norm", "float64", "",
           "RMS-over-clients L2 norm of the Sophia first-moment EMA"),
    Metric("h_norm", "float64", "",
           "RMS-over-clients L2 norm of the Sophia h-EMA diagonal"),
    Metric("h_staleness", "float64", "steps",
           "age of the curvature estimate: refresh-units since the "
           "last GNB refresh (tau-periodic sawtooth)"),
    Metric("gnb_refreshes", "float64", "count",
           "cumulative GNB Hessian-estimator refreshes per client"),
    # ---- virtual-time scheduler events (repro.sched)
    Metric("time_s", "float64", "s",
           "virtual seconds at which the event applied"),
    Metric("version", "int64", "versions",
           "server model version the event produced"),
    Metric("kind", "str", "", "event kind: round | aggregate"),
    Metric("clients", "list[int]", "",
           "client ids folded into the event"),
    Metric("staleness", "list[int]", "versions",
           "per-arrival staleness (versions applied since dispatch)"),
    Metric("weights", "list[float]", "",
           "per-arrival aggregation weights (1+staleness)^-p"),
    Metric("discipline", "str", "",
           "scheduler discipline: sync | semisync | async"),
    # ---- adversarial fleet (repro.robust): emitted only when the
    # ---- run departs from the default mean/no-attack path
    Metric("aggregator", "str", "",
           "effective robust aggregator of the event: mean | "
           "trimmed_mean | coordinate_median | norm_clip"),
    Metric("attack", "str", "",
           "active byzantine wire attack: sign_flip | scale | "
           "random_wire"),
    Metric("byzantine_clients", "list[int]", "",
           "ids of the event's participants marked byzantine"),
    Metric("dropped_clients", "list[int]", "",
           "ids of the event's participants that dropped out and "
           "rejoined (delayed arrivals)"),
    Metric("events", "int64", "count", "aggregation events in the run"),
    Metric("final_time_s", "float64", "s",
           "virtual clock at the last event"),
    Metric("staleness_hist", "hist", "",
           "[staleness, arrival-count] pairs over the whole run"),
    # ---- trace contexts (repro.obs.trace): one id per scheduler
    # ---- dispatch, threading compute -> transfer -> arrival -> apply
    Metric("trace_id", "int64", "",
           "per-dispatch trace context id on the virtual clock"),
    Metric("trace_ids", "list[int]", "",
           "trace ids of the arrivals folded into the event, aligned "
           "with clients"),
    Metric("client", "int64", "", "client id of the dispatch"),
    Metric("arrival_s", "float64", "s",
           "virtual seconds at which the uplink payload reaches the "
           "server"),
    Metric("compute_s", "float64", "s",
           "local-training compute leg of the dispatch, virtual "
           "seconds"),
    Metric("downlink_s", "float64", "s",
           "downlink transfer leg of the dispatch, virtual seconds"),
    Metric("uplink_s", "float64", "s",
           "uplink transfer leg of the dispatch, virtual seconds"),
    # ---- host-side span timers (repro.obs.spans)
    Metric("name", "str", "", "span / benchmark regime name"),
    Metric("t_wall_s", "float64", "s",
           "span start, host wall-clock relative to the span log"),
    Metric("virtual_s", "float64", "s",
           "scheduler virtual clock when the span opened"),
    # ---- serving loop (repro.launch.serve)
    Metric("tokens_per_s", "float64", "tok/s",
           "decode throughput over the whole generation loop"),
    Metric("prefill_s", "float64", "s",
           "wall-clock of the batched prefill (including cache build)"),
    Metric("decode_steps", "int64", "steps",
           "timed decode steps in the generation loop"),
    Metric("batch", "int64", "seqs", "concurrent sequences served"),
    Metric("decode_p50_ms", "float64", "ms",
           "median per-step decode latency"),
    Metric("decode_p95_ms", "float64", "ms",
           "95th-percentile per-step decode latency"),
    Metric("decode_p99_ms", "float64", "ms",
           "99th-percentile per-step decode latency"),
    # ---- engine benchmark rows (benchmarks/run.py --only engine)
    Metric("layout_ops", "int64", "ops",
           "layout-conversion primitives in the round jaxpr"),
    Metric("us_per_round", "float64", "us",
           "wall-clock per jitted round, block_until_ready"),
    Metric("state_copy_bytes", "int64", "bytes",
           "resident state not aliased in place under donation"),
    Metric("resident_state_bytes", "int64", "bytes",
           "device-resident engine state"),
    # ---- comm / sched benchmark rows (benchmarks/run.py --only
    # ---- comm|sched; committed under experiments/bench_*.json)
    Metric("hessian_bytes", "int64", "bytes",
           "hessian stream bytes, both legs, per round"),
    Metric("reduction_x", "float64", "x",
           "total wire-byte reduction vs the uncompressed baseline"),
    Metric("bytes_to_target", "int64", "bytes",
           "cumulative wire bytes when the target metric was reached"),
    Metric("target_loss", "float64", "nats",
           "loss target of the scheduled benchmark comparison"),
    Metric("sim_s_to_target", "float64", "s",
           "virtual seconds until the target loss was reached"),
    Metric("speedup_x", "float64", "x",
           "simulated wall-clock speedup vs the sync discipline"),
    Metric("max_staleness", "int64", "versions",
           "largest per-arrival staleness seen in the run"),
    Metric("accs", "list[float]", "",
           "per-eval test accuracies of the benchmark run"),
    Metric("event_times_s", "list[float]", "s",
           "per-event virtual timestamps of the benchmark trace"),
    Metric("event_eval_losses", "list[float]", "nats",
           "per-event eval losses of the benchmark trace"),
    Metric("event_cum_bytes", "list[int]", "bytes",
           "per-event cumulative wire bytes of the benchmark trace"),
)


class RecordType(NamedTuple):
    required: Tuple[str, ...]
    optional: Tuple[str, ...]


_PROBE_FIELDS = ("clip_fraction", "m_norm", "h_norm", "h_staleness",
                 "gnb_refreshes")

RECORDS: Dict[str, RecordType] = {
    # first line of every JSONL log
    "manifest": RecordType(
        required=("record", "schema_version", "schema_sha256"),
        optional=("meta",)),
    # one synchronous training round (launch/train.py)
    "round": RecordType(
        required=("record", "round", "loss", "lr", "participants",
                  "uplink_bytes", "downlink_bytes",
                  "hessian_uplink_bytes", "hessian_downlink_bytes",
                  "total_bytes", "cum_total_bytes", "energy_J",
                  "carbon_kg"),
        optional=("eval_loss", "wall_s", "comm_J", "compute_J",
                  "aggregator", "attack")
        + _PROBE_FIELDS),
    # one virtual-clock aggregation event (repro.sched.SchedEvent)
    "sched_event": RecordType(
        required=("record", "time_s", "version", "kind", "clients",
                  "staleness", "weights", "loss", "cum_uplink_bytes",
                  "cum_downlink_bytes", "cum_hessian_uplink_bytes",
                  "cum_hessian_downlink_bytes", "cum_total_bytes"),
        optional=("eval_loss", "energy_J", "carbon_kg", "trace_ids",
                  "aggregator", "attack", "byzantine_clients",
                  "dropped_clients")
        + _PROBE_FIELDS),
    # one scheduler dispatch: trace context for the compute ->
    # transfer -> arrival -> apply chain (repro.sched.SchedDispatch)
    "sched_dispatch": RecordType(
        required=("record", "trace_id", "client", "version", "time_s",
                  "arrival_s", "compute_s", "downlink_s", "uplink_s"),
        optional=("downlink_bytes", "uplink_bytes",
                  "hessian_uplink_bytes", "hessian_downlink_bytes")),
    # one per scheduler run, after its events
    "sched_summary": RecordType(
        required=("record", "discipline", "events", "final_time_s",
                  "cum_total_bytes", "staleness_hist"),
        optional=()),
    # host-side span timer (repro.obs.spans.SpanLog)
    "span": RecordType(
        required=("record", "name", "t_wall_s", "wall_s"),
        optional=("virtual_s", "trace_id")),
    # benchmark regime row (benchmarks/run.py): engine rows carry the
    # layout/us/copy gates, comm rows the per-stream byte columns,
    # sched rows the time-to-target trajectory
    "bench": RecordType(
        required=("record", "name"),
        optional=("layout_ops", "us_per_round", "state_copy_bytes",
                  "resident_state_bytes",
                  "uplink_bytes", "downlink_bytes", "hessian_bytes",
                  "total_bytes", "reduction_x", "bytes_to_target",
                  "accs", "target_loss", "sim_s_to_target",
                  "speedup_x", "events", "max_staleness",
                  "event_times_s", "event_eval_losses",
                  "event_cum_bytes")),
    # serving-loop throughput sample (repro.launch.serve)
    "serve": RecordType(
        required=("record", "tokens_per_s", "prefill_s",
                  "decode_steps", "batch"),
        optional=("decode_p50_ms", "decode_p95_ms", "decode_p99_ms")),
}


def _check_int64(name: str, v: Any) -> None:
    if isinstance(v, bool) or not isinstance(v, int):
        raise ObsSchemaError(
            f"{name}: expected an exact int64, got {type(v).__name__} "
            f"{v!r} (byte counters must never pass through floats)")
    if not _I64_MIN <= v <= _I64_MAX:
        raise ObsSchemaError(f"{name}: {v} outside the int64 range")


def _check_value(metric: Metric, v: Any) -> None:
    name, dtype = metric.name, metric.dtype
    if dtype == "int64":
        _check_int64(name, v)
    elif dtype == "float64":
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            raise ObsSchemaError(
                f"{name}: expected a number, got {type(v).__name__}")
    elif dtype == "str":
        if not isinstance(v, str):
            raise ObsSchemaError(
                f"{name}: expected a string, got {type(v).__name__}")
    elif dtype == "list[int]":
        if not isinstance(v, (list, tuple)):
            raise ObsSchemaError(f"{name}: expected a list")
        for x in v:
            _check_int64(f"{name}[]", x)
    elif dtype == "list[float]":
        if not isinstance(v, (list, tuple)):
            raise ObsSchemaError(f"{name}: expected a list")
        for x in v:
            if isinstance(x, bool) or not isinstance(x, (int, float)):
                raise ObsSchemaError(f"{name}[]: expected numbers")
    elif dtype == "hist":
        if not isinstance(v, (list, tuple)):
            raise ObsSchemaError(f"{name}: expected [bin, count] pairs")
        for pair in v:
            if not (isinstance(pair, (list, tuple)) and len(pair) == 2):
                raise ObsSchemaError(
                    f"{name}: expected [bin, count] pairs")
            _check_int64(f"{name}.bin", pair[0])
            _check_int64(f"{name}.count", pair[1])
    elif dtype == "obj":
        if not isinstance(v, dict):
            raise ObsSchemaError(f"{name}: expected an object")
    else:                                            # pragma: no cover
        raise ObsSchemaError(f"{name}: unknown dtype {dtype!r}")


def validate_record(rec: Dict[str, Any]) -> Dict[str, Any]:
    """Validate one record against the schema; returns it unchanged.

    Raises `ObsSchemaError` on an unknown record type, a missing
    required field, an unregistered field, or a dtype violation.
    """
    if not isinstance(rec, dict):
        raise ObsSchemaError(f"record must be a dict, got "
                             f"{type(rec).__name__}")
    rtype = rec.get("record")
    if rtype not in RECORDS:
        raise ObsSchemaError(
            f"unknown record type {rtype!r} (want one of "
            f"{sorted(RECORDS)})")
    rt = RECORDS[rtype]
    allowed = set(rt.required) | set(rt.optional)
    missing = [f for f in rt.required if f not in rec]
    if missing:
        raise ObsSchemaError(f"{rtype}: missing required {missing}")
    unknown = [f for f in rec if f not in allowed]
    if unknown:
        raise ObsSchemaError(
            f"{rtype}: fields {unknown} are not in the schema "
            f"(register them in repro.obs.schema first)")
    for f, v in rec.items():
        _check_value(METRICS[f], v)
    return rec


def describe() -> Dict[str, Any]:
    """The full schema as one canonical plain dict — what the golden
    test freezes and `fingerprint()` hashes."""
    return {
        "schema_version": SCHEMA_VERSION,
        "metrics": {m.name: {"dtype": m.dtype, "unit": m.unit,
                             "description": m.description}
                    for m in METRICS.values()},
        "records": {name: {"required": list(rt.required),
                           "optional": list(rt.optional)}
                    for name, rt in RECORDS.items()},
    }


def canonical_json() -> str:
    return json.dumps(describe(), sort_keys=True, indent=1) + "\n"


def fingerprint() -> str:
    """sha256 of the canonical schema dump; rides in every manifest so
    a reader can detect schema drift without parsing the registry."""
    return hashlib.sha256(canonical_json().encode()).hexdigest()
