"""Structured telemetry for the federated runtime (docs/observability.md).

The subsystem has four layers, each usable on its own:

* `repro.obs.schema`  — the versioned record schema: a registry of
  metric names/dtypes/units, the per-record-type field sets, and
  `validate_record` (exact int64 byte counters, no silent coercion).
* `repro.obs.sinks`   — JSONL file sink, bounded in-memory ring, and
  `RunRecorder`, which validates every record, fans it out to both
  sinks and writes a CI-consumable run manifest on close.
* `repro.obs.probes`  — device-side Sophia health metrics (clip
  fraction, m/h norms, curvature freshness), computed INSIDE the
  jitted round with no extra host syncs, plus `MetricsAccumulator`
  (`repro.obs.buffer`), the packed device-side metrics buffer that
  defers the host sync to the eval/checkpoint flush boundary.
* `repro.obs.spans`   — host-side span timers correlated with the
  scheduler's virtual clock, and the opt-in `jax.profiler` trace
  hooks (`--profile-dir` in `repro.launch.train` / `serve`).
* `repro.obs.trace`   — Chrome Trace Event / Perfetto export of a
  run's trace contexts (`ObsConfig.trace`), plus the structural
  validator `make obs-trace-smoke` gates on.
* `repro.obs.logio`   — tolerant record readers for finished or
  still-growing logs (JSONL, record arrays, legacy bench dicts),
  shared by every tool under tools/.
"""
from repro.obs.buffer import MetricsAccumulator
from repro.obs.logio import ObsLogError, read_records
from repro.obs.probes import PROBE_METRICS, sophia_health
from repro.obs.schema import (SCHEMA_VERSION, SUPPORTED_SCHEMA_VERSIONS,
                              ObsSchemaError, describe, fingerprint,
                              validate_record)
from repro.obs.sinks import JsonlSink, RingSink, RunRecorder
from repro.obs.spans import SpanLog, annotate, profile_trace
from repro.obs.trace import chrome_trace, validate_chrome_trace

__all__ = [
    "SCHEMA_VERSION", "SUPPORTED_SCHEMA_VERSIONS", "ObsSchemaError",
    "describe", "fingerprint", "validate_record",
    "JsonlSink", "RingSink", "RunRecorder",
    "MetricsAccumulator", "PROBE_METRICS", "sophia_health",
    "SpanLog", "annotate", "profile_trace",
    "ObsLogError", "read_records",
    "chrome_trace", "validate_chrome_trace",
]
