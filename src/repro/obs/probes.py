"""Device-side Sophia health probes (docs/observability.md).

`sophia_health` turns the persistent per-client Sophia state into the
diagnostic scalars the paper's claims ride on — how often the Eq. 11
clip binds, how large the m/h EMAs run, and how fresh the GNB
curvature estimate is.  Everything here is elementwise/reduction math
over buffers the round already produced:

* computed INSIDE the jitted round (`FedEngine.round` appends the
  probe scalars to the round metrics when ``ObsConfig.probes``) with
  zero extra host syncs — the scalars stay on device until the caller
  flushes them (`repro.obs.buffer.MetricsAccumulator`);
* pure reads of the round's outputs: the probed round's ``state`` is
  bitwise identical to the unprobed one (pinned by tests/test_obs.py);
* no layout primitives (concatenate/slice/pad), so the gated
  layout-op counts of `benchmarks/run.py --only engine` are unchanged.

The clip fraction replays the Eq. 11 decision from the final EMAs:
a coordinate was clipped iff ``|m / max(h, eps)| >= rho``.  The packed
wire buffers carry a zero pad tail (`repro.comm.flat`) where m = h = 0
gives |0/eps| < rho — pad coordinates never count as clipped, and the
fraction divides by the TRUE coordinate count, not the padded one.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.configs.base import FedConfig

#: the metric names `sophia_health` emits, in the registry
#: (`repro.obs.schema.METRICS`) — sinks and reports key off this
PROBE_METRICS = ("clip_fraction", "m_norm", "h_norm", "h_staleness",
                 "gnb_refreshes")


def sophia_health(opt, round_idx, fed: FedConfig,
                  total: int) -> Dict[str, jnp.ndarray]:
    """Health scalars from a `SophiaState` of wire-layout buffers.

    ``opt.m`` / ``opt.h`` are (rows, cols) buffers or per-client
    (C, rows, cols) stacks (any resident dtype — upcast to fp32 for
    the reductions); ``round_idx`` is the 0-based round the EMAs were
    last updated in (traced or static); ``total`` the true coordinate
    count of the layout (pad excluded).  Returns float32 scalars —
    pure reads, no layout ops, no host syncs.
    """
    m = opt.m.astype(jnp.float32)
    h = opt.h.astype(jnp.float32)
    C = m.shape[0] if m.ndim == 3 else 1
    n = C * total
    # Eq. 11 replay: was the preconditioned step at the +-rho bound?
    # float32-accumulated count: exact below ~2^24 clipped coordinates
    # per client, a <1e-7 relative error beyond — fine for a fraction.
    at_bound = jnp.abs(m / jnp.maximum(h, fed.eps)) >= fed.rho
    clip_fraction = jnp.sum(at_bound, dtype=jnp.float32) / n
    # RMS over clients of the per-client L2 norms:
    # sqrt(mean_c ||x_c||^2) — one reduction, no per-client stacking
    m_norm = jnp.sqrt(jnp.sum(m * m) / C)
    h_norm = jnp.sqrt(jnp.sum(h * h) / C)
    # curvature freshness: the GNB estimator refreshes every tau
    # refresh-units (rounds or local steps, FedConfig.hessian_every_
    # unit); staleness is the sawtooth position after this round's
    # last update, refreshes the cumulative estimator invocations
    r = jnp.asarray(round_idx, jnp.int32)
    if fed.hessian_every_unit == "round":
        last = r
    else:                       # step mode: J local steps per round
        last = (r + 1) * fed.local_iters - 1
    h_staleness = (last % fed.tau).astype(jnp.float32)
    gnb_refreshes = (last // fed.tau + 1).astype(jnp.float32)
    return {"clip_fraction": clip_fraction, "m_norm": m_norm,
            "h_norm": h_norm, "h_staleness": h_staleness,
            "gnb_refreshes": gnb_refreshes}
