"""Tolerant obs-log readers shared by the tools (tools/obs_*.py).

A "log" is any file carrying schema records:

* a JSONL run log (`repro.obs.sinks.RunRecorder`) — one record per
  line, manifest first;
* a JSON array of records (the regenerated ``experiments/
  bench_*.json`` format — manifest first, then ``bench`` rows);
* a legacy mapping of named rows (pre-v2 ``BENCH_engine.json`` /
  ``bench_*.json``): ``{name: {field: value}}`` or ``{"baseline":
  {...}, "current": {...}}`` — converted to unvalidated ``bench``
  records so old files still feed the tools.

Robustness contract (tested in tests/test_obs_tools.py): a missing,
empty or unparseable file raises `ObsLogError` with a one-line
diagnosis — never a traceback — and a TRUNCATED FINAL JSONL line
(the tail of a live or killed run) is dropped with a warning instead
of failing the whole log.  A bad line in the *middle* of a log is
still an error: that's corruption, not an in-progress write.

Pure stdlib — no jax — so tools start fast.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Any, Dict, List


class ObsLogError(Exception):
    """A log file the tools cannot read, with a one-line diagnosis."""


def _legacy_bench_records(name: str, row: Dict[str, Any],
                          prefix: str = "") -> Dict[str, Any]:
    """One legacy ``{name: {field: value}}`` row as a bench-shaped
    record (NOT schema-validated: legacy files predate the v2 field
    names and may carry retired fields)."""
    rec = {"record": "bench",
           "name": f"{prefix}{name}" if prefix else name}
    rec.update(row)
    return rec


def read_records(path: str) -> List[Dict[str, Any]]:
    """All records of an obs log, tolerant of the formats above.

    Raises `ObsLogError` (never a bare traceback) when the file is
    missing, empty, or not one of the known shapes.
    """
    p = Path(path)
    if not p.exists():
        raise ObsLogError(f"{path}: no such file")
    try:
        text = p.read_text()
    except OSError as e:
        raise ObsLogError(f"{path}: unreadable ({e})")
    if not text.strip():
        raise ObsLogError(f"{path}: empty log (the run wrote nothing)")
    # JSONL iff the first non-empty line is complete JSON on its own;
    # pretty-printed JSON files (arrays, legacy bench dicts) have an
    # unparseable first line and take the whole-document path
    first = next(l for l in text.splitlines() if l.strip())
    try:
        json.loads(first)
    except ValueError:
        return _read_json(path, text)
    if first.strip() != text.strip():
        return _read_jsonl(path, text)
    return _read_json(path, text)


def _read_jsonl(path: str, text: str) -> List[Dict[str, Any]]:
    lines = text.splitlines()
    records: List[Dict[str, Any]] = []
    for n, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            records.append(json.loads(line))
        except ValueError:
            if n == len(lines) - 1:
                # the tail of a live/killed run — drop it, keep going
                print(f"{path}: dropping truncated final line "
                      f"{n + 1}", file=sys.stderr)
                continue
            raise ObsLogError(
                f"{path}: line {n + 1} is not valid JSON (corrupt "
                f"log — only the FINAL line may be truncated)")
    if not records:
        raise ObsLogError(f"{path}: no parseable records")
    return records


def _read_json(path: str, text: str) -> List[Dict[str, Any]]:
    try:
        data = json.loads(text)
    except ValueError as e:
        raise ObsLogError(f"{path}: not valid JSON ({e})")
    if isinstance(data, list):
        if not all(isinstance(r, dict) and "record" in r for r in data):
            raise ObsLogError(
                f"{path}: JSON array entries must all be records "
                f"(objects with a 'record' field)")
        return data
    if isinstance(data, dict):
        # {"record": ...} — a single record
        if "record" in data:
            return [data]
        # legacy two-level {"baseline": {name: row}, "current": ...}
        if set(data) and all(
                isinstance(v, dict) and v
                and all(isinstance(r, dict) for r in v.values())
                for v in data.values()):
            return [_legacy_bench_records(n, r, f"{group}/")
                    for group, rows in data.items()
                    for n, r in rows.items()]
        # legacy one-level {name: row}
        if set(data) and all(isinstance(v, dict)
                             for v in data.values()):
            return [_legacy_bench_records(n, r)
                    for n, r in data.items()]
    raise ObsLogError(f"{path}: unrecognized log shape "
                      f"({type(data).__name__})")


def manifest_of(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    """The manifest record of a log, or ``{}`` when absent (legacy
    files) — callers decide whether that is an error."""
    for r in records:
        if r.get("record") == "manifest":
            return r
    return {}
