"""Minimal distributed-aware checkpointing.

Leaves are gathered to host (works for sharded arrays via device_get of
fully-addressable arrays or process-local replicas), flattened with
stable path keys, and stored as .npz + a JSON manifest. Restore rebuilds
the pytree and (optionally) re-shards with device_put against provided
shardings.

Checkpoints always store the params PYTREE — portable across packing
geometries and resident state dtypes.  The packed-resident engine
(`FedEngine.pack_state`) crosses this boundary through the explicit
shims `save_packed` / `restore_packed`: the only places (besides eval)
where its between-round wire buffers materialize a pytree.  The wire
headers stored in the manifest (`FedEngine.wire_headers`) fingerprint
the packed layout so `--resume` can reject a reinterpreting restore.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(path: str, tree: Any, step: int = 0, extra: Optional[dict] = None):
    os.makedirs(path, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)

    def to_np(v):
        # numpy's savez can't serialise bfloat16 — store as float32, the
        # manifest keeps the logical dtype and restore() casts back.
        if hasattr(v, "dtype") and v.dtype == jnp.bfloat16:
            v = jnp.asarray(v, jnp.float32)
        return np.asarray(jax.device_get(v))

    arrays = {k: to_np(v) for k, v in flat.items()}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like: Any, shardings: Optional[Any] = None):
    """Restore into the structure of ``like``; optionally device_put with a
    matching pytree of shardings."""
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = _flatten_with_paths(like)
    leaves = {}
    for key, ref in flat_like.items():
        arr = data[key]
        assert tuple(arr.shape) == tuple(ref.shape), \
            f"shape mismatch for {key}: {arr.shape} vs {ref.shape}"
        leaves[key] = jnp.asarray(arr, dtype=ref.dtype)
    restored = jax.tree_util.tree_unflatten(
        treedef, [leaves[k] for k in flat_like.keys()])
    if shardings is not None:
        restored = jax.tree.map(jax.device_put, restored, shardings)
    return restored


def load_manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


# ------------------------------------------ packed-resident state shims
def save_packed(path: str, packed, spec, step: int = 0,
                extra: Optional[dict] = None):
    """`save` for a packed (rows, cols) wire buffer: unpack through
    ``spec`` (`repro.comm.flat.FlatSpec`) and store the params pytree —
    the on-disk format is residency-agnostic, so a run that keeps
    params packed between rounds checkpoints identically to a
    tree-resident one."""
    from repro.comm import flat as cflat
    save(path, cflat.unpack(packed, spec), step=step, extra=extra)


def restore_packed(path: str, spec, dtype=jnp.float32,
                   shardings: Optional[Any] = None):
    """Restore a checkpoint directly INTO wire layout: rebuild the
    pytree from ``spec``'s shapes/dtypes, then pack it as one
    (rows, cols) buffer in the resident storage ``dtype``
    (`CommConfig.state_dtype`).  The inverse of `save_packed`."""
    from repro.comm import flat as cflat
    like = cflat.unpack(cflat.zeros(spec), spec)
    return cflat.pack(restore(path, like, shardings=shardings), spec,
                      dtype=dtype)
