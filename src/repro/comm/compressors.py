"""Stream compressors over the packed (rows, cols) fp32 wire buffer.

One compressor family serves every named stream of the round (uplink
model delta, downlink broadcast delta, hessian-EMA — `repro.configs.
base.COMM_STREAMS`): build one with `make_stream_compressor(comm,
stream, spec)`, which resolves the per-stream compressor choice via
``CommConfig.stream(name)``.

Each compressor is a pure function pair ``encode -> payload`` /
``decode -> reconstruction``, plus two fused engine entry points —
``roundtrip`` (decode(encode(x)) on an existing buffer) and
``encode_delta`` (the whole uplink chain over wire-layout state:
delta-code vs the received model, EF correction, round-trip, new
residual).  Both lower to the pure-JAX composition by default, or to
the fused Pallas kernels from `repro.kernels.quantize` when
``CommConfig.use_pallas`` is set; both paths consume the same
`jax.random` noise, so they agree to float rounding.  ``serialize``
renders a payload to its canonical little-endian wire bytes (the
normative layout in docs/wire-format.md, frozen by the golden tests).

Everything but ``serialize`` is vmap/scan-compatible: the engine calls
``roundtrip`` once per client under either execution strategy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import accounting
from repro.comm import flat as cflat
from repro.comm.flat import FlatSpec
from repro.configs.base import CommConfig

from repro.kernels import INTERPRET as _INTERPRET

Payload = Dict[str, jnp.ndarray]

# compressors whose reconstruction is a biased estimator of the input —
# these need error feedback to converge; the unbiased quantizers do not
BIASED = frozenset({"topk", "signsgd"})


def wants_error_feedback(comm: CommConfig) -> bool:
    """Whether the engine should materialise per-client EF residuals.

    ``error_feedback="auto"`` (the default) enables EF exactly for the
    biased compressors — unbiased int8/int4 would otherwise pay C full
    fp32 model copies of HBM for a variance reduction they don't need.
    """
    if comm.lossless:
        return False
    if comm.error_feedback == "auto":
        return comm.compressor in BIASED
    return bool(comm.error_feedback)


def participation_mask(key, num_clients: int,
                       num_participants: int) -> jnp.ndarray:
    """Seeded, jit-compatible uniform sample of S of C clients.

    permutation(arange(C)) assigns each client a distinct uniform rank;
    rank < S selects exactly S clients. Returns a float32 0/1 mask (C,).
    """
    ranks = jax.random.permutation(key, num_clients)
    return (ranks < num_participants).astype(jnp.float32)


def participation_indices(key, num_clients: int,
                          num_participants: int) -> jnp.ndarray:
    """The same sample as `participation_mask`, as S sorted client ids —
    the gather form, so the engine trains only the participants."""
    ranks = jax.random.permutation(key, num_clients)
    return jnp.sort(jnp.argsort(ranks)[:num_participants])


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base: lossless identity (the wire carries the raw fp32 delta)."""
    cfg: CommConfig
    spec: FlatSpec

    # -- wire format ----------------------------------------------------
    def encode(self, key, flat) -> Payload:
        del key
        return {"x": flat}

    def decode(self, payload: Payload) -> jnp.ndarray:
        return payload["x"]

    def header(self) -> cflat.Header:
        """The versioned 24-byte wire header of this stream's payloads
        (docs/wire-format.md): layout fingerprint a decoder validates
        before touching the body.  ``state_dtype`` records the storage
        dtype of resident state kept under this stream's layout (EF
        residuals, replicas) — the payload bytes themselves are always
        compressor-dtyped."""
        return cflat.Header(compressor=self.cfg.compressor,
                            total=self.spec.total,
                            quant_block=self.spec.cols,
                            state_dtype=self.cfg.state_dtype)

    def serialize(self, payload: Payload) -> bytes:
        """Canonical little-endian wire bytes of ONE payload (host-side,
        normative layout: docs/wire-format.md): the versioned header
        followed by the body.  The zero pad tail of the packed buffer
        is never transmitted; ``len(serialize(p))`` must equal
        `accounting.wire_bytes` for this compressor."""
        return self.header().pack() + self._body(payload)

    def _body(self, payload: Payload) -> bytes:
        x = np.asarray(payload["x"], dtype="<f4").reshape(-1)
        return x[: self.spec.total].tobytes()

    def stat(self, payload: Payload) -> jnp.ndarray:
        """Scalar the server aggregates alongside the decoded delta
        (signsgd majority vote needs the mean client scale)."""
        del payload
        return jnp.zeros((), jnp.float32)

    # -- engine entry points --------------------------------------------
    def roundtrip(self, key, flat) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """decode(encode(flat)) plus the aggregation stat, fused where a
        Pallas kernel exists."""
        payload = self.encode(key, flat)
        return self.decode(payload), self.stat(payload)

    def encode_delta(self, key, theta, start, ef):
        """One client's full uplink encode over wire-layout buffers:
        delta = (theta - start) [+ ef] -> round-trip -> new residual.

        The flat-resident engine's uplink entry point (`FedEngine.
        comm_client_step`): the delta never exists as a pytree.
        Returns ``(xhat, stat, new_ef)`` with ``new_ef=None`` when EF
        is off; `StochasticQuant` fuses the whole chain into one
        Pallas pass when ``use_pallas`` is set.
        """
        delta = theta - start
        if ef is not None:
            delta = delta + ef
        xhat, stat = self.roundtrip(key, delta)
        return xhat, stat, (None if ef is None else delta - xhat)

    def roundtrip_batched(self, keys, flat):
        """`roundtrip` over a packed (N, rows, cols) client stack;
        keys: the N per-client rng keys.  Returns ``(xhat, stat)``
        with a leading client axis.  Default: vmap of the per-client
        path (graph-identical to looping); the kernel-backed
        subclasses override with ONE client-batched Pallas launch,
        bitwise equal to the loop (tests/test_kernel_conformance.py).
        """
        return jax.vmap(self.roundtrip)(keys, flat)

    def encode_delta_batched(self, keys, theta, start, ef):
        """`encode_delta` over (N, rows, cols) client stacks in one
        pass.  ``start`` may stay (rows, cols) when every client
        trained from the same broadcast model (downlink replicas
        off); ``ef=None`` means EF is off for the whole cohort.
        Returns ``(xhat, stat, new_ef)`` stacked along clients."""
        start_ax = None if start.ndim == 2 else 0
        return jax.vmap(self.encode_delta,
                        in_axes=(0, 0, start_ax, 0))(keys, theta,
                                                     start, ef)

    def server_combine(self, agg, wstat):
        """Hook applied to the participation-weighted mean of decoded
        deltas (wstat = weighted mean of per-client stats)."""
        del wstat
        return agg


@dataclasses.dataclass(frozen=True)
class StochasticQuant(Compressor):
    """int8/int4 stochastic quantization, one fp32 scale per packed row.

    scale = max|row| / qmax, q = floor(x/scale + u), u ~ U[0,1):
    E[q * scale] = x, so the compressor is unbiased (up to the clip of
    the single max-magnitude coordinate).  int4 codes are simulated in
    an int8 container; byte accounting charges 4 bits (see
    repro.comm.accounting).
    """
    bits: int = 8

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    def _scales(self, flat):
        return jnp.max(jnp.abs(flat), axis=1, keepdims=True) / self.qmax

    def encode(self, key, flat) -> Payload:
        scale = self._scales(flat)
        safe = jnp.where(scale > 0, scale, 1.0)
        u = jax.random.uniform(key, flat.shape)
        q = jnp.clip(jnp.floor(flat / safe + u), -self.qmax, self.qmax)
        return {"q": q.astype(jnp.int8), "scale": scale}

    def decode(self, payload: Payload) -> jnp.ndarray:
        return payload["q"].astype(jnp.float32) * payload["scale"]

    def _body(self, payload: Payload) -> bytes:
        # [codes][group scales]; int4 packs two two's-complement
        # nibbles per byte (even coordinate in the low nibble)
        q = np.asarray(payload["q"], np.int8).reshape(-1)[: self.spec.total]
        scales = np.asarray(payload["scale"], dtype="<f4").reshape(-1)
        if self.bits == 8:
            codes = q.tobytes()
        else:
            nib = (q.astype(np.uint8) & 0xF)
            if nib.size % 2:
                nib = np.append(nib, np.uint8(0))
            codes = (nib[0::2] | (nib[1::2] << 4)).tobytes()
        return codes + scales.tobytes()

    def roundtrip(self, key, flat):
        if not self.cfg.use_pallas:
            return super().roundtrip(key, flat)
        from repro.kernels.quantize import quant_roundtrip_flat
        u = jax.random.uniform(key, flat.shape)
        xhat = quant_roundtrip_flat(flat, u, self._scales(flat),
                                    qmax=self.qmax, interpret=_INTERPRET)
        return xhat, jnp.zeros((), jnp.float32)

    def encode_delta(self, key, theta, start, ef):
        # EF off (the "auto" default for unbiased quantizers): the base
        # delta + `roundtrip` composition is already optimal — it
        # dispatches to the fused quant kernel under use_pallas without
        # streaming a zeros EF buffer or materializing a second delta
        if not self.cfg.use_pallas or ef is None:
            return super().encode_delta(key, theta, start, ef)
        # fused Pallas path: delta-code + EF + quant round-trip +
        # residual in one HBM pass (the scales need one reduction
        # over the corrected delta first) — the uplink twin of the
        # downlink `broadcast_roundtrip_flat`
        from repro.kernels.quantize import uplink_roundtrip_flat
        delta = theta - start + ef
        u = jax.random.uniform(key, delta.shape)
        xhat, resid = uplink_roundtrip_flat(
            theta, start, ef, u, self._scales(delta), qmax=self.qmax,
            interpret=_INTERPRET)
        return xhat, jnp.zeros((), jnp.float32), resid

    def roundtrip_batched(self, keys, flat):
        if not self.cfg.use_pallas:
            return super().roundtrip_batched(keys, flat)
        # ONE launch over the (N, R, C) stack; per-client noise/scales
        # match the vmapped per-client path exactly
        from repro.kernels.quantize import quant_roundtrip_batched
        u = jax.vmap(lambda k: jax.random.uniform(k, flat.shape[1:]))(keys)
        xhat = quant_roundtrip_batched(flat, u,
                                       jax.vmap(self._scales)(flat),
                                       qmax=self.qmax,
                                       interpret=_INTERPRET)
        return xhat, jnp.zeros((flat.shape[0],), jnp.float32)

    def encode_delta_batched(self, keys, theta, start, ef):
        if not self.cfg.use_pallas:
            return super().encode_delta_batched(keys, theta, start, ef)
        if ef is None:
            # EF off (the "auto" default for unbiased quantizers, and
            # the gated uplink-int8 bench regime): delta-code then the
            # batched quant kernel — a shared 2D start broadcasts
            delta = theta - start
            xhat, stat = self.roundtrip_batched(keys, delta)
            return xhat, stat, None
        # fused: delta + EF + quant round-trip + residual, one launch
        from repro.kernels.quantize import uplink_roundtrip_batched
        delta = theta - start + ef
        u = jax.vmap(lambda k: jax.random.uniform(k, theta.shape[1:]))(keys)
        xhat, resid = uplink_roundtrip_batched(
            theta, start, ef, u, jax.vmap(self._scales)(delta),
            qmax=self.qmax, interpret=_INTERPRET)
        return xhat, jnp.zeros((theta.shape[0],), jnp.float32), resid


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Magnitude top-k sparsification (biased -> wants error feedback).

    Wire format: (int32 index, fp32 value) per surviving coordinate,
    k = ceil(topk_ratio * n_params).  The zero pad tail can never win a
    slot against any nonzero coordinate, but k is capped to the true
    element count anyway.
    """

    @property
    def k(self) -> int:
        return min(accounting.topk_k(self.cfg, self.spec.total),
                   self.spec.total)

    def encode(self, key, flat) -> Payload:
        del key
        v = flat.reshape(-1)
        _, idx = jax.lax.top_k(jnp.abs(v), self.k)
        return {"idx": idx.astype(jnp.int32), "val": v[idx]}

    def decode(self, payload: Payload) -> jnp.ndarray:
        n = self.spec.padded
        flat = jnp.zeros((n,), jnp.float32).at[payload["idx"]].set(
            payload["val"])
        return flat.reshape(self.spec.rows, self.spec.cols)

    def header(self) -> cflat.Header:
        return cflat.Header(compressor=self.cfg.compressor,
                            total=self.spec.total,
                            quant_block=self.spec.cols, aux=self.k,
                            state_dtype=self.cfg.state_dtype)

    def _body(self, payload: Payload) -> bytes:
        idx = np.asarray(payload["idx"], dtype="<i4")
        val = np.asarray(payload["val"], dtype="<f4")
        return idx.tobytes() + val.tobytes()

    def roundtrip(self, key, flat):
        if not self.cfg.use_pallas:
            return super().roundtrip(key, flat)
        from repro.kernels.quantize import topk_threshold_flat
        vals = jax.lax.top_k(jnp.abs(flat.reshape(-1)), self.k)[0]
        xhat = topk_threshold_flat(flat, vals[-1], interpret=_INTERPRET)
        return xhat, jnp.zeros((), jnp.float32)

    def roundtrip_batched(self, keys, flat):
        if not self.cfg.use_pallas:
            return super().roundtrip_batched(keys, flat)
        from repro.kernels.quantize import topk_threshold_batched
        vals = jax.vmap(
            lambda f: jax.lax.top_k(jnp.abs(f.reshape(-1)), self.k)[0]
        )(flat)
        xhat = topk_threshold_batched(flat, vals[:, -1],
                                      interpret=_INTERPRET)
        return xhat, jnp.zeros((flat.shape[0],), jnp.float32)


@dataclasses.dataclass(frozen=True)
class SignSGD(Compressor):
    """1-bit sign compression with a single fp32 magnitude scale.

    decode = scale * sign(x) with scale = mean|x| (EF-signSGD).  With
    ``sign_majority`` the server additionally takes the sign of the
    scale-weighted client vote and rescales by the mean client scale —
    the majority-vote rule of Bernstein et al., weighted by magnitude.
    """

    def _scale(self, flat):
        return jnp.sum(jnp.abs(flat)) / self.spec.total

    def encode(self, key, flat) -> Payload:
        del key
        return {"sign": jnp.sign(flat).astype(jnp.int8),
                "scale": self._scale(flat)}

    def decode(self, payload: Payload) -> jnp.ndarray:
        return (payload["sign"].astype(jnp.float32)
                * payload["scale"].astype(jnp.float32))

    def stat(self, payload: Payload) -> jnp.ndarray:
        return jnp.asarray(payload["scale"], jnp.float32)

    def _body(self, payload: Payload) -> bytes:
        # [packbits(x > 0), MSB-first][fp32 scale]; the wire bit cannot
        # carry sign(0) = 0, so exact zeros decode as -scale on a real
        # link (measure-zero for float deltas; the in-graph simulation
        # keeps them at 0 — see docs/wire-format.md)
        s = np.asarray(payload["sign"], np.int8).reshape(-1)[: self.spec.total]
        bits = np.packbits(s > 0).tobytes()
        scale = np.asarray(payload["scale"], dtype="<f4").reshape(1)
        return bits + scale.tobytes()

    def roundtrip(self, key, flat):
        if not self.cfg.use_pallas:
            return super().roundtrip(key, flat)
        from repro.kernels.quantize import sign_roundtrip_flat
        scale = self._scale(flat)
        xhat = sign_roundtrip_flat(flat, scale, interpret=_INTERPRET)
        return xhat, scale

    def roundtrip_batched(self, keys, flat):
        if not self.cfg.use_pallas:
            return super().roundtrip_batched(keys, flat)
        from repro.kernels.quantize import sign_roundtrip_batched
        scale = jax.vmap(self._scale)(flat)
        xhat = sign_roundtrip_batched(flat, scale, interpret=_INTERPRET)
        return xhat, scale

    def server_combine(self, agg, wstat):
        if not self.cfg.sign_majority:
            return agg
        return wstat * jnp.sign(agg)


def make_compressor(comm: CommConfig, spec: FlatSpec) -> Compressor:
    c = comm.compressor
    if c == "identity":
        return Compressor(comm, spec)
    if c in ("int8", "int4"):
        return StochasticQuant(comm, spec, bits=int(c[3:]))
    if c == "topk":
        return TopK(comm, spec)
    if c == "signsgd":
        return SignSGD(comm, spec)
    raise ValueError(f"unknown compressor {c!r}")


def make_stream_compressor(comm: CommConfig, stream: str,
                           spec: FlatSpec) -> Compressor:
    """Compressor for one named stream of the round (`COMM_STREAMS`)."""
    return make_compressor(comm.stream(stream), spec)
