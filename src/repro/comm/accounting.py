"""Exact bytes-on-the-wire accounting for a federated round.

Single source of truth for what each compressor would actually transmit
(payload bits, not simulation container sizes — int4 codes count 4 bits
even though the simulation stores them in an int8 array).  Methodology
is documented in `benchmarks/README.md`.

All functions are pure Python over static config — call them outside
jit and feed the results to reports; `FedEngine.round` mirrors them as
float32 metrics for convenience.
"""
from __future__ import annotations

import math
from typing import Dict

from repro.configs.base import CommConfig

FP32_BITS = 32


def _num_groups(comm: CommConfig, n_params: int) -> int:
    return -(-n_params // comm.quant_block)


def topk_k(comm: CommConfig, n_params: int) -> int:
    return min(n_params, max(1, math.ceil(comm.topk_ratio * n_params)))


def wire_bits(comm: CommConfig, n_params: int) -> int:
    """Uplink payload bits for ONE client's compressed delta."""
    c = comm.compressor
    if c == "identity":
        return FP32_BITS * n_params
    if c == "int8":
        return 8 * n_params + FP32_BITS * _num_groups(comm, n_params)
    if c == "int4":
        return 4 * n_params + FP32_BITS * _num_groups(comm, n_params)
    if c == "topk":
        # (int32 index, fp32 value) per surviving coordinate
        return topk_k(comm, n_params) * (32 + FP32_BITS)
    if c == "signsgd":
        return n_params + FP32_BITS          # 1 bit/coord + one scale
    raise ValueError(f"unknown compressor {c!r}")


def wire_bytes(comm: CommConfig, n_params: int) -> int:
    return -(-wire_bits(comm, n_params) // 8)


def round_bytes(comm: CommConfig, n_params: int,
                num_clients: int) -> Dict[str, int]:
    """Per-round totals: S participants upload compressed deltas, and the
    server broadcasts the fp32 global model back to the same S clients."""
    s = comm.num_participants(num_clients)
    return {
        "participants": s,
        "uplink_bytes": s * wire_bytes(comm, n_params),
        "downlink_bytes": s * 4 * n_params,
    }
