"""Exact bytes-on-the-wire accounting for a federated round.

Single source of truth for what each of the round's named streams
(``uplink`` / ``downlink`` / ``hessian`` — see `repro.configs.base.
COMM_STREAMS` and docs/wire-format.md) would actually transmit:
payload bits, not simulation container sizes — int4 codes count 4 bits
even though the simulation stores them in an int8 array.  Per-payload
formulas live in `wire_bits`; `round_bytes` composes them into
per-round, per-stream totals (the uplink and downlink payloads are
per-participant, the averaged-curvature broadcast is one common
payload).  Methodology is documented in `benchmarks/README.md`; the
wire-format golden tests pin these numbers against serialized payloads.

All functions are pure Python over static config — call them outside
jit and feed the results to reports; `FedEngine.round` mirrors them as
float32 metrics for convenience.
"""
from __future__ import annotations

import math
from typing import Dict

from repro.comm.flat import HEADER_BYTES
from repro.configs.base import COMM_STREAMS, CommConfig

FP32_BITS = 32


def _num_groups(comm: CommConfig, n_params: int) -> int:
    return -(-n_params // comm.quant_block)


def topk_k(comm: CommConfig, n_params: int) -> int:
    return min(n_params, max(1, math.ceil(comm.topk_ratio * n_params)))


def wire_bits(comm: CommConfig, n_params: int) -> int:
    """Payload bits for ONE compressed (rows, cols) wire buffer under
    ``comm.compressor`` — pass a `CommConfig.stream(name)` view to
    price a specific stream's payload.  Every payload carries the
    24-byte versioned header of `repro.comm.flat.Header`
    (docs/wire-format.md) ahead of its body."""
    header = 8 * HEADER_BYTES
    c = comm.compressor
    if c == "identity":
        return header + FP32_BITS * n_params
    if c == "int8":
        return header + 8 * n_params \
            + FP32_BITS * _num_groups(comm, n_params)
    if c == "int4":
        return header + 4 * n_params \
            + FP32_BITS * _num_groups(comm, n_params)
    if c == "topk":
        # (int32 index, fp32 value) per surviving coordinate
        return header + topk_k(comm, n_params) * (32 + FP32_BITS)
    if c == "signsgd":
        return header + n_params + FP32_BITS   # 1 bit/coord + one scale
    raise ValueError(f"unknown compressor {c!r}")


def wire_bytes(comm: CommConfig, n_params: int) -> int:
    return -(-wire_bits(comm, n_params) // 8)


def stream_bytes(comm: CommConfig, stream: str, n_params: int) -> int:
    """Bytes of ONE payload on the named stream (0 when disabled)."""
    if stream not in COMM_STREAMS:
        raise ValueError(f"unknown stream {stream!r} (want {COMM_STREAMS})")
    if stream == "hessian" and not comm.hessian_enabled:
        return 0
    return wire_bytes(comm.stream(stream), n_params)


def round_bytes(comm: CommConfig, n_params: int,
                num_clients: int) -> Dict[str, int]:
    """Per-round, per-stream totals.

    S participants each upload a compressed model delta
    (``uplink_bytes``) and receive a per-client delta-coded broadcast
    (``downlink_bytes``; exact fp32 when the downlink stream is
    disabled).  With the hessian stream enabled, each participant also
    uploads its compressed Hessian-EMA (``hessian_uplink_bytes``) and
    the server broadcasts ONE common averaged-curvature payload
    (``hessian_downlink_bytes`` — a true broadcast, charged once, not
    per client, because unlike the model downlink it carries no
    per-client delta reference).  ``total_bytes`` sums every stream.
    """
    s = comm.num_participants(num_clients)
    up = s * stream_bytes(comm, "uplink", n_params)
    down = s * stream_bytes(comm, "downlink", n_params)
    h_up = s * stream_bytes(comm, "hessian", n_params)
    h_down = stream_bytes(comm, "hessian", n_params)
    return {
        "participants": s,
        "uplink_bytes": up,
        "downlink_bytes": down,
        "hessian_uplink_bytes": h_up,
        "hessian_downlink_bytes": h_down,
        "total_bytes": up + down + h_up + h_down,
    }
