"""Compressed server->client broadcast (the ``downlink`` stream).

PR 1 broadcast the raw fp32 global model.  This module delta-codes the
broadcast instead: the server tracks, per client, the model that client
last received (``model`` replicas, wire layout) and transmits the
compressed delta ``theta_server - theta_i^rx``, with **server-side
per-client error feedback** for biased compressors.  Unbiased
quantizers need no EF here — any reconstruction error lands in the
client's model replica and is cancelled by the next round's delta
(closed-loop delta coding) — so ``downlink_error_feedback="auto"``
mirrors the uplink policy and materialises residuals only for
``topk``/``signsgd``.

Everything operates on the shared packed (rows, cols) layout of
`repro.comm.flat`; `FedEngine._round_comm` calls `broadcast` once per
participant (under vmap or scan), and non-participants keep their
replicas frozen until they are next sampled.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.compressors import (Compressor, StochasticQuant,
                                    wants_error_feedback)
from repro.comm.flat import FlatSpec
from repro.configs.base import CommConfig

from repro.kernels import INTERPRET as _INTERPRET

#: engine state keys owned by this module
MODEL_KEY = "comm_dn_model"
EF_KEY = "comm_dn_ef"


def wants_downlink_ef(comm: CommConfig) -> bool:
    """Server-side per-client EF residuals, under the same "auto"
    policy as the uplink (biased compressors only)."""
    return comm.downlink_enabled and wants_error_feedback(
        comm.stream("downlink"))


def init_state(comm: CommConfig, spec: FlatSpec, packed_params,
               num_clients: int, dtype=jnp.float32) -> dict:
    """Server-side downlink state: every client starts exactly in sync
    (the initial model is assumed distributed out-of-band), with zero
    EF residual.  ``dtype`` is the resident storage dtype of the
    replicas/residuals (`CommConfig.state_dtype`); the engine upcasts
    gathered rows to fp32 before `broadcast` sees them."""
    if not comm.downlink_enabled:
        return {}
    state = {MODEL_KEY: jnp.broadcast_to(
        packed_params[None].astype(dtype),
        (num_clients,) + packed_params.shape).copy()}
    if wants_downlink_ef(comm):
        state[EF_KEY] = jnp.zeros(
            (num_clients, spec.rows, spec.cols), dtype)
    return state


def broadcast(comp: Compressor, key, packed_theta: jnp.ndarray,
              model_row: jnp.ndarray,
              ef_row: Optional[jnp.ndarray]
              ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """One client's broadcast step.

    Encodes ``theta_server - theta_i^rx`` (+ EF residual), applies the
    reconstruction to the client's replica, and returns
    ``(new_model_row, new_ef_row)``.  The compressed payload itself is
    what crosses the wire — `repro.comm.accounting.stream_bytes(...,
    "downlink", ...)` prices it.
    """
    cfg = comp.cfg
    if cfg.use_pallas and isinstance(comp, StochasticQuant):
        # fused Pallas path: delta-code + quant round-trip + apply +
        # residual in one HBM pass (scales need one reduction first)
        from repro.kernels.quantize import broadcast_roundtrip_flat
        ef = jnp.zeros_like(model_row) if ef_row is None else ef_row
        delta = packed_theta - model_row + ef
        u = jax.random.uniform(key, delta.shape)
        new_model, resid = broadcast_roundtrip_flat(
            packed_theta, model_row, ef, u, comp._scales(delta),
            qmax=comp.qmax, interpret=_INTERPRET)
        return new_model, (None if ef_row is None else resid)
    delta = packed_theta - model_row
    if ef_row is not None:
        delta = delta + ef_row
    xhat, _ = comp.roundtrip(key, delta)
    new_model = model_row + xhat
    new_ef = None if ef_row is None else delta - xhat
    return new_model, new_ef


def broadcast_batched(comp: Compressor, keys, packed_theta: jnp.ndarray,
                      model_rows: jnp.ndarray,
                      ef_rows: Optional[jnp.ndarray]
                      ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """`broadcast` for the whole cohort in one pass.

    keys: (N,) per-client rng keys; model_rows / ef_rows: the gathered
    (N, rows, cols) replica / residual stacks (resident dtype — the
    kernels upcast loads in-VMEM); packed_theta stays the one (rows,
    cols) server model, shared across the client grid axis.  The
    Pallas path is ONE client-batched launch; otherwise a vmap of the
    per-client step (graph-identical to looping)."""
    cfg = comp.cfg
    if cfg.use_pallas and isinstance(comp, StochasticQuant):
        from repro.kernels.quantize import broadcast_roundtrip_batched
        ef = (jnp.zeros_like(model_rows) if ef_rows is None else ef_rows)
        delta = packed_theta - model_rows + ef
        u = jax.vmap(
            lambda k: jax.random.uniform(k, delta.shape[1:]))(keys)
        new_models, resid = broadcast_roundtrip_batched(
            packed_theta, model_rows, ef, u,
            jax.vmap(comp._scales)(delta), qmax=comp.qmax,
            interpret=_INTERPRET)
        return new_models, (None if ef_rows is None else resid)
    return jax.vmap(
        lambda k, m, e: broadcast(comp, k, packed_theta, m, e)
    )(keys, model_rows, ef_rows)
