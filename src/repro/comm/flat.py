"""Flat wire-buffer layout shared by every comm stream — and, since
the flat-resident engine refactor, the canonical **in-round
representation** of all client-visible state (params, Sophia m/h,
EF residuals, downlink replicas; docs/architecture.md "Memory
layout").

Every leaf of the pytree is flattened to fp32, concatenated,
zero-padded and reshaped to a (rows, cols) buffer.  Rows double as
the quantization scale groups, so one packed layout serves every
compressor and the Pallas kernels tile it directly.  All three named
streams of a round — the uplink model delta, the downlink broadcast
delta, and the hessian-EMA — share the flattened coordinate order
(the model and its Sophia ``h`` state have identical pytree
structure) but may disagree on the (rows, cols) geometry: each
stream's ``cols`` is its own ``quant_block`` (`CommConfig.stream`),
and `repack` re-lays a buffer between stream geometries.  Only the
true ``total`` coordinates ever count as wire bytes (the pad tail is
a simulation artifact — see docs/wire-format.md).

`aval_key` fingerprints a pytree's avals so engines can memoize spec
and compressor construction across traces (`FedEngine.comm_runtime`);
`zeros` allocates flat state buffers without a donor pytree.

This module also owns the versioned wire **header** (`Header`): the
24-byte preamble every serialized payload carries, and the layout
fingerprint checkpoints store so comm/EF state written under one
config is never silently reinterpreted under another
(`check_headers`).
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

#: magic + version of the serialized wire-buffer format
WIRE_MAGIC = b"FSWB"
WIRE_VERSION = 1
#: <magic 4s><version u16><compressor u8><flags u8><total u64>
#: <quant_block u32><aux u32>, little-endian (docs/wire-format.md)
_HEADER_STRUCT = struct.Struct("<4sHBBQII")
HEADER_BYTES = _HEADER_STRUCT.size          # 24

#: stable on-the-wire compressor ids (never renumber — append only)
COMPRESSOR_IDS = {"identity": 0, "int8": 1, "int4": 2, "topk": 3,
                  "signsgd": 4}
_ID_COMPRESSORS = {v: k for k, v in COMPRESSOR_IDS.items()}


@dataclass(frozen=True)
class Header:
    """The versioned 24-byte preamble of every serialized payload.

    Also the checkpoint-manifest fingerprint of wire-layout engine
    state (uplink EF residuals, downlink replicas): restoring under a
    different geometry would silently misinterpret the packed rows, so
    `check_headers` rejects any mismatch with a clear error.

    ``aux`` carries the compressor-specific layout parameter (top-k:
    ``k``); 0 otherwise.
    """
    compressor: str
    total: int
    quant_block: int
    aux: int = 0
    version: int = WIRE_VERSION

    def pack(self) -> bytes:
        if self.compressor not in COMPRESSOR_IDS:
            raise ValueError(f"unknown compressor {self.compressor!r}")
        return _HEADER_STRUCT.pack(
            WIRE_MAGIC, self.version, COMPRESSOR_IDS[self.compressor], 0,
            self.total, self.quant_block, self.aux)

    @classmethod
    def unpack(cls, buf: bytes) -> "Header":
        if len(buf) < HEADER_BYTES:
            raise ValueError(
                f"wire buffer too short for a header: {len(buf)} < "
                f"{HEADER_BYTES} bytes")
        magic, ver, comp_id, _flags, total, qb, aux = \
            _HEADER_STRUCT.unpack_from(buf)
        if magic != WIRE_MAGIC:
            raise ValueError(
                f"not a Fed-Sophia wire buffer (magic {magic!r}, "
                f"expected {WIRE_MAGIC!r})")
        if ver != WIRE_VERSION:
            raise ValueError(
                f"unsupported wire-format version {ver} (this build "
                f"speaks version {WIRE_VERSION}); re-encode the payload "
                f"or upgrade")
        if comp_id not in _ID_COMPRESSORS:
            raise ValueError(f"unknown wire compressor id {comp_id}")
        return cls(compressor=_ID_COMPRESSORS[comp_id], total=total,
                   quant_block=qb, aux=aux, version=ver)

    def to_dict(self) -> Dict[str, Any]:
        return {"version": self.version, "compressor": self.compressor,
                "total": self.total, "quant_block": self.quant_block,
                "aux": self.aux}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Header":
        return cls(compressor=d["compressor"], total=int(d["total"]),
                   quant_block=int(d["quant_block"]),
                   aux=int(d.get("aux", 0)),
                   version=int(d.get("version", WIRE_VERSION)))


def check_headers(saved: Dict[str, Dict[str, Any]],
                  current: Dict[str, Dict[str, Any]]) -> None:
    """Validate checkpointed per-stream wire headers against the
    current engine's (`FedEngine.wire_headers`).  Raises ValueError
    naming every mismatched stream/field — comm/EF state saved under
    one layout must never be reinterpreted under another."""
    if not saved:
        raise ValueError(
            "the checkpoint manifest carries no wire headers (it "
            "predates the versioned wire format, or was saved without "
            "FedEngine.wire_headers) — cannot prove the comm/EF "
            "layouts match; re-save the checkpoint with this build")
    problems = []
    for stream in sorted(set(saved) | set(current)):
        if stream not in saved:
            problems.append(
                f"stream {stream!r}: active now but the checkpoint has "
                f"no wire header for it (saved under a config without "
                f"this stream)")
            continue
        if stream not in current:
            problems.append(
                f"stream {stream!r}: present in the checkpoint but not "
                f"active under the current config")
            continue
        s, c = saved[stream], current[stream]
        for field_ in ("version", "compressor", "total", "quant_block",
                       "aux"):
            if s.get(field_) != c.get(field_):
                problems.append(
                    f"stream {stream!r}: {field_} was "
                    f"{s.get(field_)!r} at save time but is "
                    f"{c.get(field_)!r} now")
    if problems:
        raise ValueError(
            "wire-layout mismatch between checkpoint and current comm "
            "config — restoring would misinterpret packed comm/EF "
            "state:\n  " + "\n  ".join(problems))


@dataclass(frozen=True)
class FlatSpec:
    """Static description of the packed layout (trace-time only)."""
    treedef: Any
    sizes: Tuple[int, ...]
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    total: int                 # true element count (pre-padding)
    rows: int
    cols: int

    @property
    def padded(self) -> int:
        return self.rows * self.cols


def flat_spec(tree, cols: int = 1024) -> FlatSpec:
    """Build the layout spec from a (concrete or ShapeDtypeStruct) pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = tuple(int(l.size) for l in leaves)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    total = sum(sizes)
    rows = -(-total // cols)
    return FlatSpec(treedef, sizes, shapes, dtypes, total, rows, cols)


def aval_key(tree) -> Tuple:
    """Hashable fingerprint of a pytree's structure + leaf avals.

    Works on concrete arrays, tracers and ShapeDtypeStructs alike —
    the memoization key for spec/compressor caches (specs are pure
    static metadata, so one build serves every trace of the same
    abstract shape)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (treedef, tuple((tuple(l.shape), jnp.dtype(l.dtype).str)
                           for l in leaves))


def zeros(spec: FlatSpec, lead: Tuple[int, ...] = ()) -> jnp.ndarray:
    """A zeroed flat state buffer in ``spec``'s wire layout, with
    optional leading (e.g. per-client) axes."""
    return jnp.zeros(tuple(lead) + (spec.rows, spec.cols), jnp.float32)


def pack(tree, spec: FlatSpec) -> jnp.ndarray:
    """pytree -> (rows, cols) fp32 wire buffer (zero pad at the tail)."""
    leaves = jax.tree_util.tree_flatten(tree)[0]
    v = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return jnp.pad(v, (0, spec.padded - spec.total)).reshape(
        spec.rows, spec.cols)


def unpack(flat: jnp.ndarray, spec: FlatSpec):
    """(rows, cols) buffer -> pytree with the original shapes/dtypes."""
    v = flat.reshape(-1)[:spec.total]
    out: List[jnp.ndarray] = []
    off = 0
    for sz, shp, dt in zip(spec.sizes, spec.shapes, spec.dtypes):
        out.append(v[off:off + sz].reshape(shp).astype(dt))
        off += sz
    return jax.tree_util.tree_unflatten(spec.treedef, out)


def repack(flat: jnp.ndarray, from_spec: FlatSpec,
           to_spec: FlatSpec) -> jnp.ndarray:
    """Re-lay a packed buffer from one stream's (rows, cols) geometry
    into another's (same flattened coordinates, different quant_block;
    the pad tail is re-zeroed).  Matching geometries return the buffer
    unchanged — engine state keeps its pad tail at zero invariantly, so
    same-geometry repacks need no ops in the traced graph."""
    if from_spec.total != to_spec.total:
        raise ValueError(
            f"repack between incompatible specs: total "
            f"{from_spec.total} vs {to_spec.total}")
    if (from_spec.rows, from_spec.cols) == (to_spec.rows, to_spec.cols):
        return flat
    v = flat.reshape(-1)[:from_spec.total]
    return jnp.pad(v, (0, to_spec.padded - to_spec.total)).reshape(
        to_spec.rows, to_spec.cols)
