"""Flat wire-buffer layout shared by every comm stream.

Same packed idiom as `repro.kernels.ops._pack`: every leaf of the
pytree is flattened to fp32, concatenated, zero-padded and reshaped to
a (rows, cols) buffer.  Rows double as the quantization scale groups,
so one packed layout serves every compressor and the Pallas kernels
tile it directly.  All three named streams of a round — the uplink
model delta, the downlink broadcast delta, and the hessian-EMA — share
ONE spec (the model and its Sophia ``h`` state have identical pytree
structure), so the engine packs/unpacks every stream through the same
layout; only the true ``total`` coordinates ever count as wire bytes
(the pad tail is a simulation artifact — see docs/wire-format.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class FlatSpec:
    """Static description of the packed layout (trace-time only)."""
    treedef: Any
    sizes: Tuple[int, ...]
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    total: int                 # true element count (pre-padding)
    rows: int
    cols: int

    @property
    def padded(self) -> int:
        return self.rows * self.cols


def flat_spec(tree, cols: int = 1024) -> FlatSpec:
    """Build the layout spec from a (concrete or ShapeDtypeStruct) pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = tuple(int(l.size) for l in leaves)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    total = sum(sizes)
    rows = -(-total // cols)
    return FlatSpec(treedef, sizes, shapes, dtypes, total, rows, cols)


def pack(tree, spec: FlatSpec) -> jnp.ndarray:
    """pytree -> (rows, cols) fp32 wire buffer (zero pad at the tail)."""
    leaves = jax.tree_util.tree_flatten(tree)[0]
    v = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return jnp.pad(v, (0, spec.padded - spec.total)).reshape(
        spec.rows, spec.cols)


def unpack(flat: jnp.ndarray, spec: FlatSpec):
    """(rows, cols) buffer -> pytree with the original shapes/dtypes."""
    v = flat.reshape(-1)[:spec.total]
    out: List[jnp.ndarray] = []
    off = 0
    for sz, shp, dt in zip(spec.sizes, spec.shapes, spec.dtypes):
        out.append(v[off:off + sz].reshape(shp).astype(dt))
        off += sz
    return jax.tree_util.tree_unflatten(spec.treedef, out)
