"""Flat wire-buffer layout shared by every comm stream — and, since
the flat-resident engine refactor, the canonical **in-round
representation** of all client-visible state (params, Sophia m/h,
EF residuals, downlink replicas; docs/architecture.md "Memory
layout").

Every leaf of the pytree is flattened to fp32, concatenated,
zero-padded and reshaped to a (rows, cols) buffer.  Rows double as
the quantization scale groups, so one packed layout serves every
compressor and the Pallas kernels tile it directly.  All three named
streams of a round — the uplink model delta, the downlink broadcast
delta, and the hessian-EMA — share the flattened coordinate order
(the model and its Sophia ``h`` state have identical pytree
structure) but may disagree on the (rows, cols) geometry: each
stream's ``cols`` is its own ``quant_block`` (`CommConfig.stream`),
and `repack` re-lays a buffer between stream geometries.  Only the
true ``total`` coordinates ever count as wire bytes (the pad tail is
a simulation artifact — see docs/wire-format.md).

Helper semantics (the contracts the flat-resident engine relies on):

* `aval_key(tree)` — a hashable fingerprint of a pytree's structure
  plus leaf (shape, dtype) avals.  It deliberately ignores leaf
  *values* and shardings: two pytrees with the same key pack to the
  same `FlatSpec`, so engines memoize spec/compressor construction on
  it across traces (`FedEngine.comm_runtime`).  Works on concrete
  arrays, tracers and ShapeDtypeStructs alike.
* `zeros(spec, lead, dtype)` — allocates a zeroed flat state buffer
  in ``spec``'s wire layout without a donor pytree (per-client state
  gets leading axes via ``lead``).  ``dtype`` is the *storage* dtype
  (`CommConfig.state_dtype`); the zero pad tail is a fixed point of
  every engine op, so buffers from `zeros` stay valid wire buffers
  forever.
* `repack(flat, from_spec, to_spec)` — re-lays a packed buffer
  between two stream geometries that share the flattened ``total``
  coordinate order (different ``quant_block`` ⇒ different
  (rows, cols)).  Matching geometries return the *same array object*
  (zero ops in the traced graph) — callers must not mutate the result
  in place assuming it is a copy.

Donation-safety contract: the flat-resident engine donates its state
buffers to the jitted round (`FedEngine.round_fn`), so on
donation-capable backends every buffer reachable from the state dict
passed in — packed params, (C, rows, cols) m/h/EF/replica stacks —
is INVALIDATED by the call and aliased by the returned state.  A
caller that keeps a reference (for eval, checkpointing, or a
same-geometry `repack` view) must copy it out *before* the round, or
use the undonated entry point.  See docs/architecture.md
"Memory layout: the life of a round".

This module also owns the versioned wire **header** (`Header`): the
24-byte preamble every serialized payload carries, and the layout
fingerprint checkpoints store so comm/EF state written under one
config is never silently reinterpreted under another
(`check_headers`).
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

#: magic + version of the serialized wire-buffer format.  Version 2
#: (FSWB v2) carries the resident-state dtype in the previously
#: reserved flags byte; version-1 payloads/manifests (flags = 0) are
#: still accepted and decode as float32 (docs/wire-format.md).
WIRE_MAGIC = b"FSWB"
WIRE_VERSION = 2
#: versions `Header.unpack` / `check_headers` accept
SUPPORTED_WIRE_VERSIONS = (1, 2)
#: <magic 4s><version u16><compressor u8><flags u8><total u64>
#: <quant_block u32><aux u32>, little-endian (docs/wire-format.md).
#: flags (v2): low 4 bits = state-dtype id, high 4 bits reserved.
_HEADER_STRUCT = struct.Struct("<4sHBBQII")
HEADER_BYTES = _HEADER_STRUCT.size          # 24

#: stable on-the-wire compressor ids (never renumber — append only)
COMPRESSOR_IDS = {"identity": 0, "int8": 1, "int4": 2, "topk": 3,
                  "signsgd": 4}
_ID_COMPRESSORS = {v: k for k, v in COMPRESSOR_IDS.items()}

#: stable state-dtype ids carried in the v2 flags byte (append only);
#: 0 == float32 keeps v1 payloads (flags == 0) meaning what they meant.
#: ids 2/3 are the fp8 resident formats (E4M3 for moments, E5M2 for
#: the wider-range hessian EMA — docs/wire-format.md)
STATE_DTYPE_IDS = {"float32": 0, "bfloat16": 1,
                   "float8_e4m3fn": 2, "float8_e5m2": 3}
_ID_STATE_DTYPES = {v: k for k, v in STATE_DTYPE_IDS.items()}
#: name -> storage dtype; one registry for validation AND lookup, so
#: appending a dtype id without its jnp mapping is a loud error, never
#: a silent float32 fallback
_STATE_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                 "float8_e4m3fn": jnp.float8_e4m3fn,
                 "float8_e5m2": jnp.float8_e5m2}
assert set(_STATE_DTYPES) == set(STATE_DTYPE_IDS)


def as_dtype(state_dtype: str):
    """`CommConfig.state_dtype` name -> jnp dtype (storage dtype of
    the resident wire-layout state)."""
    try:
        return _STATE_DTYPES[state_dtype]
    except KeyError:
        raise ValueError(
            f"unknown state_dtype {state_dtype!r} "
            f"(want one of {tuple(_STATE_DTYPES)})") from None


@dataclass(frozen=True)
class Header:
    """The versioned 24-byte preamble of every serialized payload.

    Also the checkpoint-manifest fingerprint of wire-layout engine
    state (uplink EF residuals, downlink replicas): restoring under a
    different geometry would silently misinterpret the packed rows, so
    `check_headers` rejects any mismatch with a clear error.

    ``aux`` carries the compressor-specific layout parameter (top-k:
    ``k``); 0 otherwise.  ``state_dtype`` (v2) is the storage dtype of
    resident wire-layout state written under this header — the wire
    *payload* bytes are dtype'd by the compressor, not this field.
    Version-1 headers decode with ``state_dtype="float32"``.
    """
    compressor: str
    total: int
    quant_block: int
    aux: int = 0
    version: int = WIRE_VERSION
    state_dtype: str = "float32"

    def pack(self) -> bytes:
        if self.compressor not in COMPRESSOR_IDS:
            raise ValueError(f"unknown compressor {self.compressor!r}")
        if self.state_dtype not in STATE_DTYPE_IDS:
            raise ValueError(f"unknown state_dtype {self.state_dtype!r}")
        flags = STATE_DTYPE_IDS[self.state_dtype]
        if self.version == 1 and flags:
            raise ValueError(
                "wire-format v1 cannot carry a non-float32 state_dtype "
                "(the flags byte was reserved = 0); write v2")
        return _HEADER_STRUCT.pack(
            WIRE_MAGIC, self.version, COMPRESSOR_IDS[self.compressor],
            flags, self.total, self.quant_block, self.aux)

    @classmethod
    def unpack(cls, buf: bytes) -> "Header":
        if len(buf) < HEADER_BYTES:
            raise ValueError(
                f"wire buffer too short for a header: {len(buf)} < "
                f"{HEADER_BYTES} bytes")
        magic, ver, comp_id, flags, total, qb, aux = \
            _HEADER_STRUCT.unpack_from(buf)
        if magic != WIRE_MAGIC:
            raise ValueError(
                f"not a Fed-Sophia wire buffer (magic {magic!r}, "
                f"expected {WIRE_MAGIC!r})")
        if ver not in SUPPORTED_WIRE_VERSIONS:
            raise ValueError(
                f"unsupported wire-format version {ver} (this build "
                f"speaks versions {SUPPORTED_WIRE_VERSIONS}); re-encode "
                f"the payload or upgrade")
        if comp_id not in _ID_COMPRESSORS:
            raise ValueError(f"unknown wire compressor id {comp_id}")
        if ver == 1:
            # v1 reserved the flags byte: anything nonzero is corrupt
            if flags:
                raise ValueError(
                    f"wire-format v1 header with nonzero reserved flags "
                    f"byte ({flags:#x})")
            sdt = "float32"
        else:
            if flags & 0xF0:
                # the high nibble is reserved = 0 in v2: nonzero means
                # corruption or a future format this build can't read
                raise ValueError(
                    f"wire-format v2 header with nonzero reserved flag "
                    f"bits ({flags:#x})")
            dt_id = flags & 0x0F
            if dt_id not in _ID_STATE_DTYPES:
                raise ValueError(f"unknown wire state-dtype id {dt_id}")
            sdt = _ID_STATE_DTYPES[dt_id]
        return cls(compressor=_ID_COMPRESSORS[comp_id], total=total,
                   quant_block=qb, aux=aux, version=ver, state_dtype=sdt)

    def to_dict(self) -> Dict[str, Any]:
        return {"version": self.version, "compressor": self.compressor,
                "total": self.total, "quant_block": self.quant_block,
                "aux": self.aux, "state_dtype": self.state_dtype}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Header":
        # v1 manifests predate the state_dtype field: default float32
        return cls(compressor=d["compressor"], total=int(d["total"]),
                   quant_block=int(d["quant_block"]),
                   aux=int(d.get("aux", 0)),
                   version=int(d.get("version", 1)),
                   state_dtype=d.get("state_dtype", "float32"))


def check_headers(saved: Dict[str, Dict[str, Any]],
                  current: Dict[str, Dict[str, Any]]) -> None:
    """Validate checkpointed per-stream wire headers against the
    current engine's (`FedEngine.wire_headers`).  Raises ValueError
    naming every mismatched stream/field — comm/EF state saved under
    one layout must never be reinterpreted under another.

    Versioning: headers saved under any `SUPPORTED_WIRE_VERSIONS`
    format load under the current one — a v1 manifest (no
    ``state_dtype`` field) is exactly a v2 header with
    ``state_dtype="float32"``, so upgrading the build never orphans a
    checkpoint; only the *layout* fields (compressor, total,
    quant_block, aux) must match.  ``state_dtype`` is deliberately NOT
    compared: checkpoints store the dtype-agnostic params pytree (the
    resident EF/replica/optimizer buffers are rebuilt on restore, not
    read back), so the resident storage dtype is a runtime choice —
    resuming an fp32 run with ``state_dtype="bfloat16"`` (or back) is
    a supported upgrade, not a reinterpretation."""
    if not saved:
        raise ValueError(
            "the checkpoint manifest carries no wire headers (it "
            "predates the versioned wire format, or was saved without "
            "FedEngine.wire_headers) — cannot prove the comm/EF "
            "layouts match; re-save the checkpoint with this build")
    problems = []
    for stream in sorted(set(saved) | set(current)):
        if stream not in saved:
            problems.append(
                f"stream {stream!r}: active now but the checkpoint has "
                f"no wire header for it (saved under a config without "
                f"this stream)")
            continue
        if stream not in current:
            problems.append(
                f"stream {stream!r}: present in the checkpoint but not "
                f"active under the current config")
            continue
        s, c = saved[stream], current[stream]
        for d, when in ((s, "save time"), (c, "now")):
            ver = int(d.get("version", 1))
            if ver not in SUPPORTED_WIRE_VERSIONS:
                problems.append(
                    f"stream {stream!r}: wire-format version {ver} "
                    f"({when}) is not supported by this build "
                    f"({SUPPORTED_WIRE_VERSIONS})")
        for field_ in ("compressor", "total", "quant_block", "aux"):
            if s.get(field_) != c.get(field_):
                problems.append(
                    f"stream {stream!r}: {field_} was "
                    f"{s.get(field_)!r} at save time but is "
                    f"{c.get(field_)!r} now")
    if problems:
        raise ValueError(
            "wire-layout mismatch between checkpoint and current comm "
            "config — restoring would misinterpret packed comm/EF "
            "state:\n  " + "\n  ".join(problems))


@dataclass(frozen=True)
class FlatSpec:
    """Static description of the packed layout (trace-time only)."""
    treedef: Any
    sizes: Tuple[int, ...]
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    total: int                 # true element count (pre-padding)
    rows: int
    cols: int

    @property
    def padded(self) -> int:
        return self.rows * self.cols


def flat_spec(tree, cols: int = 1024) -> FlatSpec:
    """Build the layout spec from a (concrete or ShapeDtypeStruct) pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = tuple(int(l.size) for l in leaves)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    total = sum(sizes)
    rows = -(-total // cols)
    return FlatSpec(treedef, sizes, shapes, dtypes, total, rows, cols)


def aval_key(tree) -> Tuple:
    """Hashable fingerprint of a pytree's structure + leaf avals.

    Works on concrete arrays, tracers and ShapeDtypeStructs alike —
    the memoization key for spec/compressor caches (specs are pure
    static metadata, so one build serves every trace of the same
    abstract shape)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (treedef, tuple((tuple(l.shape), jnp.dtype(l.dtype).str)
                           for l in leaves))


def zeros(spec: FlatSpec, lead: Tuple[int, ...] = (),
          dtype=jnp.float32) -> jnp.ndarray:
    """A zeroed flat state buffer in ``spec``'s wire layout, with
    optional leading (e.g. per-client) axes.

    ``dtype`` is the STORAGE dtype of the buffer (resident engine
    state follows `CommConfig.state_dtype`); in-round compute always
    upcasts to fp32.  Zero is exactly representable in every supported
    dtype, and the pad tail is a fixed point of all engine ops, so the
    result is a valid wire buffer under any later `unpack`/`repack`.
    """
    return jnp.zeros(tuple(lead) + (spec.rows, spec.cols), dtype)


def pack(tree, spec: FlatSpec, dtype=jnp.float32) -> jnp.ndarray:
    """pytree -> (rows, cols) wire buffer (zero pad at the tail).

    Leaves are flattened via fp32 (the canonical wire precision) and
    the buffer is stored as ``dtype`` — fp32 by default, or a narrower
    resident format (bf16, fp8 e4m3/e5m2) when the caller keeps
    resident state per `CommConfig.state_dtype` / `moment_dtype` /
    `hessian_dtype` (a value-rounding, layout-preserving cast)."""
    leaves = jax.tree_util.tree_flatten(tree)[0]
    v = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return jnp.pad(v, (0, spec.padded - spec.total)).reshape(
        spec.rows, spec.cols).astype(dtype)


def unpack(flat: jnp.ndarray, spec: FlatSpec):
    """(rows, cols) buffer -> pytree with the original shapes/dtypes.

    The returned leaves are *views-then-casts* of ``flat``: for fp32
    models this is bit-exact round-tripping of `pack`; a narrower
    resident buffer (bf16, fp8) upcasts losslessly (every supported
    storage format ⊂ fp32)."""
    v = flat.reshape(-1)[:spec.total]
    out: List[jnp.ndarray] = []
    off = 0
    for sz, shp, dt in zip(spec.sizes, spec.shapes, spec.dtypes):
        out.append(v[off:off + sz].reshape(shp).astype(dt))
        off += sz
    return jax.tree_util.tree_unflatten(spec.treedef, out)


def repack(flat: jnp.ndarray, from_spec: FlatSpec,
           to_spec: FlatSpec) -> jnp.ndarray:
    """Re-lay a packed buffer from one stream's (rows, cols) geometry
    into another's (same flattened coordinates, different quant_block;
    the pad tail is re-zeroed; the storage dtype is preserved).
    Matching geometries return the buffer — the SAME array object, not
    a copy — engine state keeps its pad tail at zero invariantly, so
    same-geometry repacks need no ops in the traced graph.  Callers
    must treat the result as aliasing the input (see the
    donation-safety contract in the module docstring)."""
    if from_spec.total != to_spec.total:
        raise ValueError(
            f"repack between incompatible specs: total "
            f"{from_spec.total} vs {to_spec.total}")
    if (from_spec.rows, from_spec.cols) == (to_spec.rows, to_spec.cols):
        return flat
    v = flat.reshape(-1)[:from_spec.total]
    return jnp.pad(v, (0, to_spec.padded - to_spec.total)).reshape(
        to_spec.rows, to_spec.cols)
