"""repro.comm — client<->server communication layer.

Models a federated round as three named wire streams over one packed
(rows, cols) fp32 buffer layout (`repro.configs.base.COMM_STREAMS`):

* ``uplink`` — each participant's model delta, compressed with optional
  per-client error feedback (`compressors`).
* ``downlink`` — the server broadcast, delta-coded against each
  client's last-received model replica with server-side per-client
  error feedback (`downlink`).
* ``hessian`` — optional Sophia h-EMA uplink + ONE common
  averaged-curvature broadcast back (curvature averaging).

Each stream resolves its own compressor through
``CommConfig.stream(name)``, so one compressor family (identity / int8
/ int4 stochastic quant / top-k / signsgd) serves all of them, backed
by the same fused Pallas kernels.  `accounting` prices every stream's
exact bytes on the wire; `Compressor.serialize` renders payloads to
the canonical byte layout specified in docs/wire-format.md and frozen
by the wire-format golden tests.  See `repro.core.fed.FedEngine.
_round_comm` for the integration point and `benchmarks/README.md` for
the accounting methodology.
"""
from repro.comm.accounting import (round_bytes, stream_bytes, wire_bits,
                                   wire_bytes)
from repro.comm.compressors import (make_compressor, make_stream_compressor,
                                    participation_mask)
from repro.comm.flat import FlatSpec, flat_spec, pack, unpack

__all__ = [
    "FlatSpec", "flat_spec", "pack", "unpack",
    "make_compressor", "make_stream_compressor", "participation_mask",
    "wire_bits", "wire_bytes", "stream_bytes", "round_bytes",
]
