"""repro.comm — client<->server communication layer.

Models the uplink/downlink of a federated round as an explicit pipeline:
pack the client param-delta into a flat wire buffer, compress it
(optionally with per-client error feedback), aggregate the decoded
deltas over the sampled participants, and account for every byte that
would cross the wire.  See `repro.core.fed.FedEngine._round_comm` for
the integration point and `benchmarks/README.md` for the accounting
methodology.
"""
from repro.comm.accounting import round_bytes, wire_bits, wire_bytes
from repro.comm.compressors import make_compressor, participation_mask
from repro.comm.flat import FlatSpec, flat_spec, pack, unpack

__all__ = [
    "FlatSpec", "flat_spec", "pack", "unpack",
    "make_compressor", "participation_mask",
    "wire_bits", "wire_bytes", "round_bytes",
]
