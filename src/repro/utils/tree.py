"""Pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_mean_axis0(tree):
    """Mean over a leading (client) axis of every leaf."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


def tree_dot(a, b):
    leaves = jax.tree.leaves(jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b))
    return sum(leaves)


def tree_sq_norm(tree):
    return tree_dot(tree, tree)


def tree_count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_any_nan(tree):
    flags = [jnp.any(jnp.isnan(x)) for x in jax.tree.leaves(tree)
             if jnp.issubdtype(x.dtype, jnp.floating)]
    return jnp.any(jnp.stack(flags)) if flags else jnp.asarray(False)
