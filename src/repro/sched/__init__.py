"""repro.sched — virtual-time asynchronous & semi-synchronous rounds.

The engine (`repro.core.fed.FedEngine`) models idealized synchronous
rounds; this package puts those rounds on a deterministic virtual
clock with per-client latencies and drives three round disciplines
over the same comm-path client step:

* ``sync``     — today's behaviour, bit-exact; a round costs its
  slowest participant's latency.
* ``semisync`` — FedBuff-style buffered aggregation (first
  ``buffer_size`` arrivals per round, staleness-weighted mean;
  stragglers deliver stale deltas into later buffers).
* ``async``    — every arrival applied immediately with the
  staleness-decayed weight ``(1 + tau)^-staleness_power``.

`latency` is the deterministic per-client latency model (compute
seconds per local step + transfer seconds from the comm layer's exact
per-stream byte counts); `scheduler.VirtualScheduler` is the event
loop.  Configuration lives in `repro.configs.base.SchedConfig`; see
docs/scheduling.md for the data flow and `benchmarks/run.py --only
sched` for the wall-clock-to-target-loss comparison.
"""
from repro.sched.latency import (client_multipliers, dispatch_legs,
                                 dispatch_seconds, leg_bytes, stragglers)
from repro.sched.scheduler import (SchedDispatch, SchedEvent, SchedTrace,
                                   VirtualScheduler)

__all__ = [
    "client_multipliers", "dispatch_legs", "dispatch_seconds",
    "leg_bytes", "stragglers",
    "SchedDispatch", "SchedEvent", "SchedTrace", "VirtualScheduler",
]
