"""Virtual-time event scheduler over `FedEngine` (repro.sched).

A deterministic discrete-event simulator: the *virtual clock* is pure
host arithmetic over the latency model (`repro.sched.latency`), while
all model math stays in jitted JAX calls that reuse the engine's own
comm-path client step (`FedEngine.comm_client_step_batched`) — the
same downlink-replica / error-feedback / compressor bookkeeping as
the synchronous round, driven one dispatch group at a time through
the client-batched kernel launches.

Disciplines (``SchedConfig.discipline``):

* ``sync``     — delegates each event to ``FedEngine.round`` verbatim
  (bit-identical to the existing engine); the event takes as long as
  the round's slowest participant.
* ``semisync`` — FedBuff-style buffered aggregation: the first
  ``buffer_size`` arrivals form the round; the server applies their
  staleness-weighted **mean** and immediately re-dispatches them,
  while stragglers keep training and deliver stale deltas into a
  later buffer.  With ``buffer_size == num_clients``, full
  participation and uniform latencies this is bit-identical to the
  synchronous comm path (under partial participation the disciplines
  differ by construction: sync resamples its cohort every round,
  while the event loop keeps the version-0 cohort in flight).
* ``async``    — every arrival is applied immediately (buffer of one)
  with the **unnormalized** staleness-decayed weight
  ``(1 + staleness)^-staleness_power`` (FedAsync-style mixing).

Staleness ``tau`` of an arrival is the number of server model
versions applied between its dispatch and its arrival.  Applying an
aggregate bumps the server version; a client dispatched at version
``v`` trains with ``round_idx = v`` (LR schedule and Sophia refresh
timing follow the dispatch-time version).

Execution note: a dispatch's client math runs eagerly at dispatch
time (the broadcast must see the then-current server model — exactly
the replica semantics of `repro.comm.downlink`); only its *delivery*
is deferred to the arrival's virtual timestamp.  Everything the clock
decides (latencies, arrival order, buffer membership, staleness) is
deterministic in the configured seeds, so a run replays bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import accounting
from repro.comm import downlink as cdown
from repro.comm import flat as cflat
from repro.configs.base import SCHED_DISCIPLINES
from repro.core.schedules import lr_at_round
from repro.kernels import INTERPRET as _INTERPRET
from repro.obs.spans import SpanLog
from repro.robust import aggregators as robust_agg
from repro.robust import attacks as robust_attacks
from repro.sched import latency


@dataclasses.dataclass(frozen=True)
class SchedEvent:
    """One aggregation event of the virtual clock.

    Byte counters are EXACT Python ints from the accounting model
    (`repro.comm.accounting.stream_bytes`) — ``cum_bytes`` is the
    all-streams total and always equals the sum of the four per-stream
    counters; ``probes`` carries the Sophia health scalars
    (`repro.obs.probes`) when the engine runs with
    ``ObsConfig.probes``."""
    time: float               # virtual seconds at which it was applied
    version: int              # server model version it produced
    kind: str                 # "round" (sync) | "aggregate"
    clients: Tuple[int, ...]  # arrivals folded into this event
    staleness: Tuple[int, ...]
    weights: Tuple[float, ...]
    loss: float               # mean local-training loss of the arrivals
    cum_bytes: int            # cumulative wire bytes, all streams
    eval_loss: Optional[float] = None
    # exact cumulative per-stream wire bytes (all = 0 only before the
    # first dispatch)
    cum_uplink_bytes: int = 0
    cum_downlink_bytes: int = 0
    cum_hessian_uplink_bytes: int = 0
    cum_hessian_downlink_bytes: int = 0
    probes: Optional[Dict[str, float]] = None
    # trace ids of the arrivals folded into this event, aligned with
    # ``clients`` — populated only under ``ObsConfig.trace``
    trace_ids: Tuple[int, ...] = ()
    # adversarial-fleet context (repro.robust): the *effective*
    # aggregator that combined this event's arrivals, the wire attack
    # in play, the byzantine arrivals among ``clients``, and the
    # arrivals that were dropout/rejoin deliveries — all defaults
    # (hence absent from records) for non-adversarial runs
    aggregator: str = "mean"
    attack: str = "none"
    byzantine: Tuple[int, ...] = ()
    dropped: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class SchedDispatch:
    """One dispatch's trace context (``ObsConfig.trace``): the
    compute -> transfer -> arrival chain of a single client on the
    virtual clock, with its exact per-leg byte prices.

    Leg durations come from `latency.dispatch_legs` — a decomposition
    of the lumped `latency.dispatch_seconds` the clock runs on, so
    their sum may differ from ``arrival - time`` in the last ulps;
    ``arrival`` is authoritative."""
    trace_id: int             # unique per run, 1-based, dispatch order
    client: int
    version: int              # server version it trained against
    time: float               # virtual seconds at dispatch
    arrival: float            # virtual seconds at delivery
    compute_s: float
    downlink_s: float
    uplink_s: float
    downlink_bytes: int = 0
    uplink_bytes: int = 0
    hessian_uplink_bytes: int = 0
    hessian_downlink_bytes: int = 0

    def to_record(self) -> Dict[str, Any]:
        return {
            "record": "sched_dispatch", "trace_id": self.trace_id,
            "client": self.client, "version": self.version,
            "time_s": self.time, "arrival_s": self.arrival,
            "compute_s": self.compute_s,
            "downlink_s": self.downlink_s, "uplink_s": self.uplink_s,
            "downlink_bytes": self.downlink_bytes,
            "uplink_bytes": self.uplink_bytes,
            "hessian_uplink_bytes": self.hessian_uplink_bytes,
            "hessian_downlink_bytes": self.hessian_downlink_bytes}

    @staticmethod
    def from_record(r: Dict[str, Any]) -> "SchedDispatch":
        return SchedDispatch(
            trace_id=r["trace_id"], client=r["client"],
            version=r["version"], time=r["time_s"],
            arrival=r["arrival_s"], compute_s=r["compute_s"],
            downlink_s=r["downlink_s"], uplink_s=r["uplink_s"],
            downlink_bytes=r.get("downlink_bytes", 0),
            uplink_bytes=r.get("uplink_bytes", 0),
            hessian_uplink_bytes=r.get("hessian_uplink_bytes", 0),
            hessian_downlink_bytes=r.get("hessian_downlink_bytes", 0))


@dataclasses.dataclass
class SchedTrace:
    """The full event log of one scheduler run."""
    discipline: str
    events: List[SchedEvent] = dataclasses.field(default_factory=list)
    # per-dispatch trace contexts (empty unless ``ObsConfig.trace``)
    dispatches: List[SchedDispatch] = dataclasses.field(
        default_factory=list)

    @property
    def final_time(self) -> float:
        return self.events[-1].time if self.events else 0.0

    @property
    def total_bytes(self) -> int:
        return self.events[-1].cum_bytes if self.events else 0

    def _target_event(self, target_loss: float) -> Optional[SchedEvent]:
        for ev in self.events:
            loss = ev.eval_loss if ev.eval_loss is not None else ev.loss
            if loss <= target_loss:
                return ev
        return None

    def time_to_target(self, target_loss: float) -> Optional[float]:
        """Virtual seconds until the (eval) loss first reached target."""
        ev = self._target_event(target_loss)
        return None if ev is None else ev.time

    def bytes_to_target(self, target_loss: float) -> Optional[int]:
        ev = self._target_event(target_loss)
        return None if ev is None else ev.cum_bytes

    def staleness_hist(self) -> Dict[int, int]:
        """staleness value -> arrival count, over the whole run (the
        per-discipline staleness histogram of docs/observability.md)."""
        hist: Dict[int, int] = {}
        for ev in self.events:
            for t in ev.staleness:
                hist[t] = hist.get(t, 0) + 1
        return hist

    def to_records(self, channel=None) -> List[Dict[str, Any]]:
        """The trace as obs schema records: one ``sched_event`` per
        event (plus its probe scalars, when present) and one final
        ``sched_summary`` with the staleness histogram.  With a
        `repro.metrics.energy.ChannelModel`, each event also carries
        the transmission energy/carbon of its byte DELTA at the
        Shannon rate.  `from_records` inverts this exactly."""
        from repro.metrics import energy as _energy
        recs: List[Dict[str, Any]] = []
        prev_bytes = 0
        for ev in self.events:
            r: Dict[str, Any] = {
                "record": "sched_event", "time_s": ev.time,
                "version": ev.version, "kind": ev.kind,
                "clients": list(ev.clients),
                "staleness": list(ev.staleness),
                "weights": list(ev.weights), "loss": ev.loss,
                "cum_uplink_bytes": ev.cum_uplink_bytes,
                "cum_downlink_bytes": ev.cum_downlink_bytes,
                "cum_hessian_uplink_bytes": ev.cum_hessian_uplink_bytes,
                "cum_hessian_downlink_bytes":
                    ev.cum_hessian_downlink_bytes,
                "cum_total_bytes": ev.cum_bytes}
            if ev.eval_loss is not None:
                r["eval_loss"] = ev.eval_loss
            if channel is not None:
                r["energy_J"] = _energy.tx_energy_joules(
                    ev.cum_bytes - prev_bytes, channel)
                r["carbon_kg"] = _energy.footprint_kg_co2(r["energy_J"])
            prev_bytes = ev.cum_bytes
            if ev.probes:
                r.update(ev.probes)
            if ev.trace_ids:
                r["trace_ids"] = list(ev.trace_ids)
            if ev.aggregator != "mean":
                r["aggregator"] = ev.aggregator
            if ev.attack != "none":
                r["attack"] = ev.attack
            if ev.byzantine:
                r["byzantine_clients"] = list(ev.byzantine)
            if ev.dropped:
                r["dropped_clients"] = list(ev.dropped)
            recs.append(r)
        recs.extend(d.to_record() for d in self.dispatches)
        recs.append({
            "record": "sched_summary", "discipline": self.discipline,
            "events": len(self.events), "final_time_s": self.final_time,
            "cum_total_bytes": self.total_bytes,
            "staleness_hist": [[k, v] for k, v in
                               sorted(self.staleness_hist().items())]})
        return recs

    @staticmethod
    def from_records(records) -> "SchedTrace":
        """Rebuild a trace from `to_records` output (e.g. a parsed
        JSONL log).  Derived fields (energy/carbon) are recomputable,
        so the round trip ``to_records(from_records(to_records(t)))``
        is exact — pinned by tests/test_obs.py."""
        from repro.obs.probes import PROBE_METRICS
        events: List[SchedEvent] = []
        dispatches: List[SchedDispatch] = []
        discipline = None
        for r in records:
            if r.get("record") == "sched_summary":
                discipline = r["discipline"]
            elif r.get("record") == "sched_dispatch":
                dispatches.append(SchedDispatch.from_record(r))
            elif r.get("record") == "sched_event":
                probes = {k: r[k] for k in PROBE_METRICS if k in r}
                events.append(SchedEvent(
                    time=r["time_s"], version=r["version"],
                    kind=r["kind"], clients=tuple(r["clients"]),
                    staleness=tuple(r["staleness"]),
                    weights=tuple(r["weights"]), loss=r["loss"],
                    cum_bytes=r["cum_total_bytes"],
                    eval_loss=r.get("eval_loss"),
                    cum_uplink_bytes=r["cum_uplink_bytes"],
                    cum_downlink_bytes=r["cum_downlink_bytes"],
                    cum_hessian_uplink_bytes=r["cum_hessian_uplink_bytes"],
                    cum_hessian_downlink_bytes=r[
                        "cum_hessian_downlink_bytes"],
                    probes=probes or None,
                    trace_ids=tuple(r.get("trace_ids", ())),
                    aggregator=r.get("aggregator", "mean"),
                    attack=r.get("attack", "none"),
                    byzantine=tuple(r.get("byzantine_clients", ())),
                    dropped=tuple(r.get("dropped_clients", ()))))
        if discipline is None:
            raise ValueError(
                "no sched_summary record — not a to_records() trace")
        return SchedTrace(discipline=discipline, events=events,
                          dispatches=dispatches)


@dataclasses.dataclass
class _InFlight:
    """One dispatched client's precomputed results awaiting delivery."""
    arrival: float
    version: int
    wire: Any
    stat: Any
    loss: float
    ef: Any = None
    opt: Any = None
    dnm: Any = None
    dnef: Any = None
    trace_id: int = 0         # 0 when tracing is off
    dropped: bool = False     # delivery delayed by a dropout/rejoin


class VirtualScheduler:
    """Drives `FedEngine` rounds on a virtual clock.

    ``batch_fn(version) -> pytree`` must return a batch pytree with
    leading client axis ``C`` for the given server version (clients
    dispatched at version ``v`` train on their row of
    ``batch_fn(v)``); ``eval_fn(params) -> scalar loss`` is optional
    and sampled every ``eval_every`` aggregations (it always receives
    the params *pytree* — packed-resident state is unpacked at this
    boundary).

    ``donate=True`` donates end to end: the state to the sync-round
    and apply jits (resident buffers update in place on
    donation-capable backends), the dispatch group's batches to the
    dispatch jit, and the stacked wire/stat/client-state-row buffers
    of each aggregation to the apply jit — every buffer that is
    consumed by its call is handed to XLA instead of being recopied
    per group.  Donation contract: the state passed to `run` is
    consumed — its buffers are invalidated by the first aggregation —
    and ``batch_fn`` results are consumed by the dispatch that reads
    them, so under ``donate=True`` ``batch_fn`` must return fresh
    buffers per version (host/numpy pytrees are always safe: jit
    re-commits them to device each call).  Callers keep only the
    returned state.  The default is undonated (state and batches
    survive `run`, e.g. for side-by-side comparisons).
    State residency follows the engine: tree- and packed-resident
    state (`FedEngine.pack_state`) both work, at any
    `CommConfig.state_dtype` (incl. per-buffer fp8 via
    `moment_dtype`/`hessian_dtype`).
    """

    def __init__(self, engine, batch_fn: Callable[[int], Any],
                 eval_fn: Optional[Callable[[Any], Any]] = None,
                 eval_every: int = 1, donate: bool = False):
        fed = engine.fed
        sched = fed.sched
        comm = fed.comm
        if sched.discipline not in SCHED_DISCIPLINES:
            raise ValueError(
                f"unknown schedule discipline {sched.discipline!r} "
                f"(want one of {SCHED_DISCIPLINES})")
        if comm.hessian_enabled and sched.discipline != "sync":
            raise ValueError(
                "the hessian stream's curvature averaging is a round-"
                "synchronous collective (one common broadcast per "
                "round); use discipline='sync' or disable "
                "hessian_compressor")
        self.engine = engine
        self.fed = fed
        self.sched = sched
        self.comm = comm
        self.batch_fn = batch_fn
        self.eval_fn = eval_fn
        self.eval_every = max(1, eval_every)
        C = fed.num_clients
        self.num_clients = C
        self.cohort = comm.num_participants(C)
        if sched.discipline == "semisync":
            k = sched.buffer_size or self.cohort
            if not 1 <= k <= self.cohort:
                raise ValueError(
                    f"buffer_size={sched.buffer_size} must be in "
                    f"[1, {self.cohort}] (the in-flight cohort)")
            self.buffer_size = k
        else:
            self.buffer_size = 1           # async applies every arrival
        self._stateful = (fed.optimizer == "fed_sophia"
                          and fed.persistent_client_state)
        # adversarial fleet (repro.robust): the byzantine mask is a
        # static host constant folded into the dispatch jit; churn
        # draws come from a dedicated host rng stream consumed per
        # dispatch (in group order), so runs replay bit-for-bit —
        # and are consumed AT ALL only when churn is configured
        rb = fed.robust
        self.robust = rb
        self._byz_mask = robust_attacks.byzantine_mask(rb, C)
        self._attack_on = robust_attacks.wire_attack_active(rb, C)
        self._churn_on = rb.dropout_prob > 0.0
        self._churn_rng = np.random.default_rng([rb.seed, 3])
        self._round_fn = engine.round_fn(donate=donate)
        self._donate = donate
        # dispatch READS the state (its outputs are per-client rows,
        # not a new state), so the state argument never donates there
        # — but the dispatch group's batches are consumed by the call
        # (the batch cache resets after a donating dispatch), and the
        # apply step donates the state plus its stacked
        # wire/stat/client-state-row buffers (freshly stacked per
        # aggregation, never reused afterwards)
        self._dispatch_fn = jax.jit(
            self._dispatch_impl,
            donate_argnums=(1,) if donate else ())
        self._apply_fn = jax.jit(
            self._apply_impl,
            donate_argnums=(0, 1, 2, 5, 6, 7, 8) if donate else ())
        self._batch_cache: Tuple[int, Any] = (-1, None)
        # host-side span timers (docs/observability.md): every
        # dispatch/apply/round is timed and correlated with the
        # virtual clock; launchers read `spans.records()`
        self.spans = SpanLog()
        # Sophia health probes per event (`repro.obs.probes`): the
        # sync discipline reads them out of the round metrics; the
        # event loop probes the post-apply state through this jit
        self._probes_on = fed.obs.probes
        self._probe_fn = (jax.jit(engine.probe_metrics)
                          if self._probes_on else None)
        # per-dispatch trace contexts (`ObsConfig.trace`): pure host
        # bookkeeping — ids, leg durations and byte prices ride the
        # trace/spans, never the jitted math, so the traced run's
        # state is bitwise identical to the untraced one
        self._trace_on = fed.obs.trace

    # ---------------------------------------------------------- jit bodies
    def _dispatch_impl(self, state, batches, idx, rng_v, round_idx):
        """Run the comm-path client step for the dispatch group ``idx``
        against the current server model (client-batched, same math as
        `_round_comm`).  The server model is packed ONCE into the
        canonical wire layout; the dispatch group runs as ONE
        client-batched step (`FedEngine.comm_client_step_batched`) —
        gathered rows keep the resident dtype (the kernels upcast
        loads in-VMEM), and the Pallas path is one launch per fused
        op with the dispatch group as a grid axis."""
        engine = self.engine
        params = state["params"]
        rt = engine.runtime_for(params)
        lr = lr_at_round(self.fed, round_idx)
        theta = (params.astype(jnp.float32)
                 if engine.params_packed(params)
                 else cflat.pack(params, rt.spec))
        theta_dn = (cflat.repack(theta, rt.spec, rt.spec_dn)
                    if rt.dn_on else None)

        def take(tree):
            return (None if tree is None
                    else jax.tree.map(lambda x: x[idx], tree))

        opts_g = take(state.get("client_opt") if self._stateful
                      else None)
        ef_g = take(state.get("comm_ef"))
        dnm_g = take(state.get(cdown.MODEL_KEY))
        dnef_g = take(state.get(cdown.EF_KEY))
        batches_g = take(batches)
        rngs_g = jax.vmap(lambda i: jax.random.fold_in(rng_v, i))(idx)

        out = engine.comm_client_step_batched(
            rt, theta, theta_dn, round_idx, lr,
            opts_g, ef_g, dnm_g, dnef_g, batches_g, rngs_g)
        if self._attack_on:
            # byzantine rows of the dispatch group mount the
            # configured transform on their packed uplink wire buffer
            # (repro.robust.attacks); benign runs never trace this
            wires = robust_attacks.attack_wires(
                self.robust, out[0],
                jnp.asarray(self._byz_mask)[idx], rng_v)
            out = (wires,) + out[1:]
        return out

    def _apply_impl(self, state, wires, stats, weights, idx,
                    ef_rows, opt_rows, dnm_rows, dnef_rows):
        """Apply one staleness-weighted aggregate of K arrivals.

        semisync normalizes (weighted mean, FedBuff); async applies the
        raw ``(1+tau)^-p``-weighted delta (FedAsync mixing).  Scatters
        the arrivals' client-state rows back alongside.
        """
        engine = self.engine
        comm = self.comm
        params = state["params"]
        rt = engine.runtime_for(params)
        packed = engine.params_packed(params)
        normalize = self.sched.discipline == "semisync"
        wsum = jnp.sum(weights)
        inv_norm = (1.0 / wsum) if normalize else jnp.float32(1.0)
        if robust_agg.resolve(self.robust, wires.shape[0]) != "mean":
            # robust combine of the arrival stack (same staleness
            # weights and normalization semantics); degenerate
            # parameterizations resolve to "mean" above and keep the
            # stale_accum path below untouched — bitwise
            agg_flat = robust_agg.aggregate_stack(
                self.robust, wires, weights, normalize=normalize,
                use_pallas=comm.use_pallas, interpret=_INTERPRET)
        elif comm.use_pallas:
            from repro.kernels.stale_accum import stale_accum_flat
            agg_flat = stale_accum_flat(wires, weights, inv_norm,
                                        interpret=_INTERPRET)
        else:
            w3 = weights[:, None, None]
            agg_flat = jnp.sum(wires * w3, axis=0)
            agg_flat = agg_flat / wsum if normalize else agg_flat
        wstat = jnp.sum(stats * weights)
        if normalize:
            wstat = wstat / wsum
        agg_flat = rt.comp.server_combine(agg_flat, wstat)
        theta = (params.astype(jnp.float32) if packed
                 else cflat.pack(params, rt.spec))
        if rt.dn_on:
            # arrivals trained from their OWN received replicas: fold
            # in each arrival's (replica - current model) reference
            # shift, weighted like its delta
            packed_now = cflat.repack(theta, rt.spec, rt.spec_dn)
            dn_acc = jnp.sum(dnm_rows * weights[:, None, None], axis=0)
            if normalize:
                corr = dn_acc / wsum - packed_now
            else:
                corr = dn_acc - wsum * packed_now
            agg_flat = agg_flat + cflat.repack(corr, rt.spec_dn, rt.spec)
        # flat axpy + ONE unpack at the state boundary (no per-leaf
        # delta application; none at all in packed-resident mode)
        if packed:
            state = engine._apply_aggregate_flat(state, theta + agg_flat)
        else:
            state = engine._apply_aggregate(
                state, cflat.unpack(theta + agg_flat, rt.spec))
        state = {**state, "round": state["round"] + 1}
        # scatters downcast the arrivals' rows back to the resident
        # storage dtype (no-op for fp32)
        if self._stateful and opt_rows is not None:
            state = {**state, "client_opt": jax.tree.map(
                lambda full, g: full.at[idx].set(g),
                state["client_opt"], engine._store_opt(opt_rows))}
        if ef_rows is not None:
            state = {**state, "comm_ef": state["comm_ef"].at[idx].set(
                engine._store(ef_rows))}
        if dnm_rows is not None:
            state = {**state, cdown.MODEL_KEY:
                     state[cdown.MODEL_KEY].at[idx].set(
                         engine._store(dnm_rows))}
        if dnef_rows is not None:
            state = {**state, cdown.EF_KEY:
                     state[cdown.EF_KEY].at[idx].set(
                         engine._store(dnef_rows))}
        return state

    # ------------------------------------------------------------- helpers
    def _batches(self, version: int):
        # dispatches only ever draw the CURRENT version's batches, so a
        # one-entry cache suffices (async runs see many versions)
        if self._batch_cache[0] != version:
            self._batch_cache = (version, self.batch_fn(version))
        return self._batch_cache[1]

    def _maybe_eval(self, state, version: int,
                    final: bool) -> Optional[float]:
        if self.eval_fn is None:
            return None
        if final or (version % self.eval_every) == 0:
            # packed-resident state materializes the params pytree
            # only here, at the eval boundary
            return float(self.eval_fn(self.engine.unpack_params(state)))
        return None

    def _weight(self, staleness: int) -> float:
        return float((1.0 + staleness) ** (-self.sched.staleness_power))

    def _event_ctx(self, ids, dropped=()) -> Dict[str, Any]:
        """Adversarial-fleet fields of one event (`repro.robust`): the
        effective aggregator for this event's arrival count, the wire
        attack in play, and the byzantine arrivals among ``ids`` —
        all defaults for non-adversarial runs, so existing traces and
        their records are unchanged."""
        return {
            "aggregator": robust_agg.resolve(self.robust, len(ids)),
            "attack": self.robust.attack if self._attack_on else "none",
            "byzantine": tuple(i for i in ids if self._byz_mask[i]),
            "dropped": tuple(dropped)}

    def _event_probes(self, state=None,
                      metrics=None) -> Optional[Dict[str, float]]:
        """Sophia health scalars of one event (None when probing is
        off): sync rounds computed them inside the round jit already
        (pass ``metrics``); the event loop probes the post-apply
        ``state``.  The host sync this forces lands on values the
        event record fetches anyway (loss is float()ed per event)."""
        if not self._probes_on:
            return None
        if metrics is not None:
            from repro.obs.probes import PROBE_METRICS
            return {k: float(metrics[k]) for k in PROBE_METRICS}
        return {k: float(v) for k, v in self._probe_fn(state).items()}

    # ----------------------------------------------------------------- run
    def run(self, state, num_events: int, rng, *,
            target_loss: Optional[float] = None,
            stop_at_target: bool = False):
        """Advance the virtual clock through ``num_events`` aggregation
        events (sync: rounds).  Returns ``(state, SchedTrace)``;
        with ``stop_at_target`` the run ends at the first event whose
        (eval) loss reaches ``target_loss``.
        """
        if self.sched.discipline == "sync":
            return self._run_sync(state, num_events, rng, target_loss,
                                  stop_at_target)
        return self._run_event_loop(state, num_events, rng, target_loss,
                                    stop_at_target)

    def _run_sync(self, state, num_events, rng, target_loss,
                  stop_at_target):
        fed, comm = self.fed, self.comm
        C = self.num_clients
        n_params = self.engine.num_params(state)
        durations = latency.dispatch_seconds(fed, n_params, C)
        per_round = accounting.round_bytes(comm, n_params, C)
        legs = (latency.dispatch_legs(fed, n_params, C)
                if self._trace_on else None)
        stream_dn = accounting.stream_bytes(comm, "downlink", n_params)
        stream_up = accounting.stream_bytes(comm, "uplink", n_params)
        stream_h = accounting.stream_bytes(comm, "hessian", n_params)
        trace = SchedTrace(discipline="sync")
        now, cum_bytes, next_tid = 0.0, 0, 1
        cum = {"uplink_bytes": 0, "downlink_bytes": 0,
               "hessian_uplink_bytes": 0, "hessian_downlink_bytes": 0}
        for v in range(num_events):
            rng_v = jax.random.fold_in(rng, v)
            # participation is a pure function of rng_v (the round jit
            # re-derives the same sample), so reading it pre-round for
            # the trace context changes nothing downstream
            part = np.asarray(self.engine.round_participants(rng_v))
            tids: Tuple[int, ...] = ()
            if self._trace_on:
                tids = tuple(range(next_tid, next_tid + len(part)))
                next_tid += len(part)
                for tid, i in zip(tids, part):
                    trace.dispatches.append(SchedDispatch(
                        trace_id=tid, client=int(i), version=v,
                        time=now, arrival=now + float(durations[i]),
                        downlink_s=float(legs[0][i]),
                        compute_s=float(legs[1][i]),
                        uplink_s=float(legs[2][i]),
                        downlink_bytes=stream_dn,
                        uplink_bytes=stream_up,
                        hessian_uplink_bytes=stream_h,
                        hessian_downlink_bytes=stream_h))
            with self.spans.span("round", virtual_s=now,
                                 trace_id=tids[0] if tids else None):
                state, metrics = self._round_fn(state, self._batches(v),
                                                rng_v)
            now += float(np.max(durations[part]))
            cum_bytes += per_round["total_bytes"]
            for k in cum:
                cum[k] += per_round[k]
            final = v == num_events - 1
            ev = SchedEvent(
                time=now, version=v + 1, kind="round",
                clients=tuple(int(i) for i in part),
                staleness=(0,) * len(part),
                weights=(1.0,) * len(part),
                loss=float(metrics["loss"]), cum_bytes=cum_bytes,
                eval_loss=self._maybe_eval(state, v + 1, final),
                cum_uplink_bytes=cum["uplink_bytes"],
                cum_downlink_bytes=cum["downlink_bytes"],
                cum_hessian_uplink_bytes=cum["hessian_uplink_bytes"],
                cum_hessian_downlink_bytes=cum["hessian_downlink_bytes"],
                probes=self._event_probes(metrics=metrics),
                trace_ids=tids,
                **self._event_ctx([int(i) for i in part]))
            trace.events.append(ev)
            if self._hit_target(ev, target_loss, stop_at_target):
                break
        return state, trace

    def _run_event_loop(self, state, num_events, rng, target_loss,
                        stop_at_target):
        fed, comm = self.fed, self.comm
        C = self.num_clients
        n_params = self.engine.num_params(state)
        durations = latency.dispatch_seconds(fed, n_params, C)
        down_bytes, up_bytes = latency.leg_bytes(comm, n_params)
        # per-stream pricing of one leg: the hessian payload rides both
        # legs when enabled (`latency.leg_bytes`), so the lumped leg
        # totals always decompose as down = dn + h, up = up + h
        stream_dn = accounting.stream_bytes(comm, "downlink", n_params)
        stream_up = accounting.stream_bytes(comm, "uplink", n_params)
        stream_h = accounting.stream_bytes(comm, "hessian", n_params)
        legs = (latency.dispatch_legs(fed, n_params, C)
                if self._trace_on else None)
        trace = SchedTrace(discipline=self.sched.discipline)
        inflight: Dict[int, _InFlight] = {}
        buffer: List[Tuple[int, _InFlight]] = []
        now, version, cum_bytes = 0.0, 0, 0
        next_tid = 1
        cum = {"uplink_bytes": 0, "downlink_bytes": 0,
               "hessian_uplink_bytes": 0, "hessian_downlink_bytes": 0}

        def dispatch(group, at_time):
            nonlocal cum_bytes, next_tid
            group = sorted(group)
            idx = jnp.asarray(group, jnp.int32)
            rng_v = jax.random.fold_in(rng, version)
            with self.spans.span("dispatch", virtual_s=at_time,
                                 trace_id=(next_tid if self._trace_on
                                           else None)):
                (wires, stats, ef_new, opt_new, losses, dnm_new,
                 dnef_new, _h, _hs) = self._dispatch_fn(
                    state, self._batches(version), idx, rng_v,
                    jnp.asarray(version, jnp.int32))
                if self._donate:
                    # the dispatch consumed (donated) the cached
                    # batches — drop the invalidated object so a
                    # same-version lookup never resurrects it
                    self._batch_cache = (-1, None)

                def row(tree, pos):
                    return (None if tree is None
                            else jax.tree.map(lambda x: x[pos], tree))

                for pos, i in enumerate(group):
                    # dropout/rejoin on the virtual clock: the client
                    # goes offline mid-round and delivers its (stale)
                    # update rejoin_delay_s after coming back — one
                    # host rng draw per dispatched client, in group
                    # order, so replays are deterministic
                    extra, was_dropped = 0.0, False
                    if self._churn_on and (self._churn_rng.random()
                                           < self.robust.dropout_prob):
                        extra = float(self.robust.rejoin_delay_s)
                        was_dropped = True
                    arrival = at_time + float(durations[i]) + extra
                    tid = 0
                    if self._trace_on:
                        tid, next_tid = next_tid, next_tid + 1
                        trace.dispatches.append(SchedDispatch(
                            trace_id=tid, client=i, version=version,
                            time=at_time,
                            arrival=arrival,
                            downlink_s=float(legs[0][i]),
                            compute_s=float(legs[1][i]),
                            uplink_s=float(legs[2][i]),
                            downlink_bytes=stream_dn,
                            uplink_bytes=stream_up,
                            hessian_uplink_bytes=stream_h,
                            hessian_downlink_bytes=stream_h))
                    inflight[i] = _InFlight(
                        arrival=arrival,
                        version=version,
                        wire=wires[pos], stat=stats[pos],
                        loss=float(losses[pos]),
                        ef=row(ef_new, pos), opt=row(opt_new, pos),
                        dnm=row(dnm_new, pos), dnef=row(dnef_new, pos),
                        trace_id=tid, dropped=was_dropped)
                    cum_bytes += down_bytes
                    cum["downlink_bytes"] += stream_dn
                    cum["hessian_downlink_bytes"] += stream_h

        # initial cohort: the participation sample of version 0; the
        # same clients stay in flight for the whole run (delivering
        # re-dispatches them), so `participation` is the concurrency
        part0 = np.asarray(self.engine.round_participants(
            jax.random.fold_in(rng, 0)))
        dispatch([int(i) for i in part0], now)

        def stack(rows):
            if rows[0] is None:
                return None
            return jax.tree.map(lambda *xs: jnp.stack(xs), *rows)

        while version < num_events and inflight:
            i = min(inflight, key=lambda j: (inflight[j].arrival, j))
            rec = inflight.pop(i)
            now = rec.arrival
            cum_bytes += up_bytes
            cum["uplink_bytes"] += stream_up
            cum["hessian_uplink_bytes"] += stream_h
            buffer.append((i, rec))
            if len(buffer) < self.buffer_size:
                continue
            ids = [i for i, _ in buffer]
            recs = [r for _, r in buffer]
            stale = [version - r.version for r in recs]
            weights = [self._weight(t) for t in stale]
            tids = (tuple(r.trace_id for r in recs)
                    if self._trace_on else ())
            with self.spans.span("apply", virtual_s=now,
                                 trace_id=(min(tids) if tids
                                           else None)):
                state = self._apply_fn(
                    state,
                    jnp.stack([r.wire for r in recs]),
                    jnp.stack([r.stat for r in recs]),
                    jnp.asarray(weights, jnp.float32),
                    jnp.asarray(ids, jnp.int32),
                    stack([r.ef for r in recs]),
                    stack([r.opt for r in recs]),
                    stack([r.dnm for r in recs]),
                    stack([r.dnef for r in recs]))
            version += 1
            final = version == num_events
            ev = SchedEvent(
                time=now, version=version, kind="aggregate",
                clients=tuple(ids), staleness=tuple(stale),
                weights=tuple(weights),
                loss=float(np.mean([r.loss for r in recs])),
                cum_bytes=cum_bytes,
                eval_loss=self._maybe_eval(state, version, final),
                cum_uplink_bytes=cum["uplink_bytes"],
                cum_downlink_bytes=cum["downlink_bytes"],
                cum_hessian_uplink_bytes=cum["hessian_uplink_bytes"],
                cum_hessian_downlink_bytes=cum["hessian_downlink_bytes"],
                probes=self._event_probes(state=state),
                trace_ids=tids,
                **self._event_ctx(ids, dropped=[
                    i for i, r in zip(ids, recs) if r.dropped]))
            trace.events.append(ev)
            buffer = []
            if self._hit_target(ev, target_loss, stop_at_target):
                break
            if not final:
                dispatch(ids, now)        # delivered clients go again
        return state, trace

    @staticmethod
    def _hit_target(ev: SchedEvent, target_loss, stop_at_target) -> bool:
        if target_loss is None or not stop_at_target:
            return False
        loss = ev.eval_loss if ev.eval_loss is not None else ev.loss
        return loss <= target_loss
