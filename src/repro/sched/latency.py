"""Deterministic per-client latency model for the virtual clock.

Every client gets a speed multiplier drawn once, deterministically,
from ``SchedConfig.seed`` (numpy Generator — no JAX arrays, the clock
is pure host math).  One dispatch for client ``i`` then takes

    T_i = bytes_down / B_i  +  J * compute_s * m_i  +  bytes_up / B_i

virtual seconds, where ``m_i`` is the multiplier, ``B_i =
bandwidth_bps / 8 / m_i`` (slow clients are slow on both legs), ``J``
is ``FedConfig.local_iters`` and the per-stream byte counts are the
comm layer's exact wire totals (`repro.comm.accounting.stream_bytes`)
— compression does not just shrink the reported bytes, it shortens the
simulated round.

Profiles (`repro.configs.base.LATENCY_PROFILES`):

* ``uniform``   — every client identical (multiplier 1).
* ``straggler`` — a seeded ``straggler_frac`` of clients are
  ``straggler_slowdown`` x slower; everyone else is 1.
* ``lognormal`` — multipliers ~ LogNormal(0, ``lognormal_sigma``),
  the classic heavy-tailed device-heterogeneity model.
"""
from __future__ import annotations

import numpy as np

from repro.comm import accounting
from repro.configs.base import (LATENCY_PROFILES, CommConfig, FedConfig,
                                SchedConfig)


def client_multipliers(sched: SchedConfig, num_clients: int) -> np.ndarray:
    """(C,) per-client slowdown multipliers, deterministic in
    ``sched.seed`` (the virtual clock must replay bit-for-bit)."""
    if sched.latency_profile not in LATENCY_PROFILES:
        raise ValueError(
            f"unknown latency profile {sched.latency_profile!r} "
            f"(want one of {LATENCY_PROFILES})")
    rng = np.random.default_rng(sched.seed)
    mult = np.ones(num_clients, np.float64)
    if sched.latency_profile == "straggler":
        k = max(1, int(round(sched.straggler_frac * num_clients)))
        slow = rng.permutation(num_clients)[:k]
        mult[slow] = sched.straggler_slowdown
    elif sched.latency_profile == "lognormal":
        mult = rng.lognormal(mean=0.0, sigma=sched.lognormal_sigma,
                             size=num_clients)
    return mult


def stragglers(sched: SchedConfig, num_clients: int) -> np.ndarray:
    """Client ids with an above-median multiplier (empty for uniform)."""
    mult = client_multipliers(sched, num_clients)
    return np.where(mult > np.median(mult))[0]


def leg_bytes(comm: CommConfig, n_params: int):
    """(downlink, uplink) wire bytes of ONE dispatch for one client.

    The hessian stream rides both legs when enabled: its uplink
    payload travels with the model delta, and the common averaged-
    curvature broadcast still crosses this client's link once.
    """
    down = accounting.stream_bytes(comm, "downlink", n_params) \
        + accounting.stream_bytes(comm, "hessian", n_params)
    up = accounting.stream_bytes(comm, "uplink", n_params) \
        + accounting.stream_bytes(comm, "hessian", n_params)
    return down, up


def dispatch_seconds(fed: FedConfig, n_params: int,
                     num_clients: int) -> np.ndarray:
    """(C,) virtual seconds from dispatch to arrival, per client."""
    sched = fed.sched
    mult = client_multipliers(sched, num_clients)
    down, up = leg_bytes(fed.comm, n_params)
    bytes_per_s = sched.bandwidth_bps / 8.0 / mult
    compute = fed.local_iters * sched.compute_s * mult
    return (down + up) / bytes_per_s + compute


def dispatch_legs(fed: FedConfig, n_params: int, num_clients: int):
    """Per-leg durations of one dispatch: ``(downlink_s, compute_s,
    uplink_s)``, each (C,).

    Trace-context decomposition of `dispatch_seconds` for the
    Chrome/Perfetto exporter (repro.obs.trace).  The virtual clock
    stays on `dispatch_seconds`' lumped arithmetic — its float
    evaluation order is pinned by committed trajectories — so the leg
    sum may differ from it in the last ulps; the arrival timestamp is
    always authoritative.
    """
    sched = fed.sched
    mult = client_multipliers(sched, num_clients)
    down, up = leg_bytes(fed.comm, n_params)
    bytes_per_s = sched.bandwidth_bps / 8.0 / mult
    compute = fed.local_iters * sched.compute_s * mult
    return down / bytes_per_s, compute, up / bytes_per_s
