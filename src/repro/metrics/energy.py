"""Energy / carbon-footprint model of the paper (Eq. 13-14, Table II).

E_total(k) = E_c(k) + E_t(k)
  E_c: per-local-iteration compute energy summed over rounds
  E_t: transmission energy = bits(model) / R * P_t per round
  R   = B log2(1 + P_t / (d * B * N0))      (Shannon, paper §V-A)

Paper constants: P_t = 100 mW, B = 2 MHz, N0 = 1e-9 W/Hz, 100x100 m area,
uniform client-PS distance; 32-bit parameters.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

CARBON_KG_PER_MJ = 0.12 / 3.6   # ~0.12 kg-CO2/kWh grid intensity


@dataclass(frozen=True)
class ChannelModel:
    p_t: float = 0.1            # W
    bandwidth: float = 2e6      # Hz
    n0: float = 1e-9            # W/Hz
    distance: float = 50.0      # m (uniform within 100x100 area)
    bits_per_param: int = 32

    def rate(self) -> float:
        snr = self.p_t / (self.distance * self.bandwidth * self.n0)
        return self.bandwidth * math.log2(1.0 + snr)

    def tx_energy_per_round(self, num_params: int) -> float:
        """Joules to upload one model vector (Eq. 14 E_t term)."""
        bits = num_params * self.bits_per_param
        return bits / self.rate() * self.p_t


def tx_energy_joules(n_bytes: int,
                     channel: ChannelModel = ChannelModel()) -> float:
    """Eq. 14's transmission-energy term over EXACT wire bytes.

    ``tx_energy_per_round`` prices a raw 32-bit parameter vector; the
    comm layer's compressed streams transmit far fewer bytes, so
    per-round telemetry (docs/observability.md) prices the accounting
    model's exact per-stream byte counts instead:

        E_t = 8 * n_bytes / R * P_t,   R = B log2(1 + P_t/(d B N0))
    """
    return 8.0 * n_bytes / channel.rate() * channel.p_t


@dataclass(frozen=True)
class ComputeModel:
    """Per-local-iteration energy: FLOPs / (device FLOP/s) * device power."""
    device_flops: float = 1e12
    device_power: float = 10.0   # W (edge device)

    def energy_per_iteration(self, flops_per_iter: float) -> float:
        return flops_per_iter / self.device_flops * self.device_power


def round_energy(num_params: int, flops_per_iter: float, local_iters: int,
                 hessian_iters: int = 0, hessian_flop_mult: float = 1.0,
                 channel: ChannelModel = ChannelModel(),
                 compute: ComputeModel = ComputeModel()) -> dict:
    """Energy per communication round per client, in Joules.

    hessian_iters: local iterations that additionally run the GNB
    estimator (one extra fwd+bwd -> hessian_flop_mult ~ 1.0 of a step).
    """
    e_c = compute.energy_per_iteration(flops_per_iter) * (
        local_iters + hessian_iters * hessian_flop_mult)
    e_t = channel.tx_energy_per_round(num_params)
    return {"compute_J": e_c, "comm_J": e_t, "total_J": e_c + e_t}


def footprint_kg_co2(total_joules: float) -> float:
    return total_joules / 1e6 * CARBON_KG_PER_MJ
