"""Generic transformer/hybrid stack covering all 10 assigned architectures.

Layers are stacked per block-pattern position and iterated with
``lax.scan`` so HLO size (and therefore 512-device compile time) is O(1)
in depth. Pattern remainder layers (e.g. recurrentgemma's trailing 2
recurrent blocks) are unrolled singly.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import recurrent as R

ATTN_KINDS = ("attn", "local", "global")


def _mixer_kind(cfg: ModelConfig, kind: str) -> str:
    if kind in ATTN_KINDS and cfg.mla is not None:
        return "mla"
    return kind


def _effective_kind(cfg: ModelConfig, kind: str) -> str:
    """gemma2 long-context serving mode: global layers fall back to SWA."""
    if kind == "global" and cfg.long_mode_swa_only:
        return "local"
    return kind


# --------------------------------------------------------------------------
# single block init / apply
# --------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str, dtype):
    km = _mixer_kind(cfg, kind)
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if km == "mla":
        p["mixer"] = L.init_mla(ks[0], cfg, dtype)
    elif km in ATTN_KINDS:
        p["mixer"] = L.init_attention(ks[0], cfg, dtype)
    elif km == "rec":
        p["mixer"] = R.init_rglru(ks[0], cfg, dtype)
    elif km == "m":
        p["mixer"] = R.init_mlstm(ks[0], cfg, dtype)
    elif km == "s":
        p["mixer"] = R.init_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        p["post_ln1"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.d_ff > 0 or cfg.moe is not None:
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        if cfg.moe is not None:
            p["ffn"] = L.init_moe(ks[1], cfg, dtype)
        else:
            p["ffn"] = L.init_ffn(ks[1], cfg.d_model, cfg.d_ff,
                                  cfg.ffn_kind, dtype)
        if cfg.post_norm:
            p["post_ln2"] = jnp.ones((cfg.d_model,), dtype)
    return p


def apply_block(p, cfg: ModelConfig, kind: str, x, positions, *,
                cache=None, pos=None):
    """Returns (x, new_cache, aux_loss)."""
    kind = _effective_kind(cfg, kind)
    km = _mixer_kind(cfg, kind)
    h = L.rms_norm(x, p["ln1"])
    if km == "mla":
        mix, new_cache = L.mla_apply(p["mixer"], cfg, h, positions,
                                     cache=cache, pos=pos)
    elif km in ATTN_KINDS:
        mix, new_cache = L.attention_apply(p["mixer"], cfg, h, positions,
                                           kind=kind, cache=cache, pos=pos)
    elif km == "rec":
        mix, new_cache = R.rglru_apply(p["mixer"], cfg, h, positions,
                                       cache=cache, pos=pos)
    elif km == "m":
        mix, new_cache = R.mlstm_apply(p["mixer"], cfg, h, positions,
                                       cache=cache, pos=pos)
    elif km == "s":
        mix, new_cache = R.slstm_apply(p["mixer"], cfg, h, positions,
                                       cache=cache, pos=pos)
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        mix = L.rms_norm(mix, p["post_ln1"])
    x = x + cfg.residual_scale * mix

    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        h = L.rms_norm(x, p["ln2"])
        if cfg.moe is not None:
            f, aux = L.moe_apply(p["ffn"], cfg, h)
        else:
            f = L.ffn_apply(p["ffn"], cfg.ffn_kind, h)
        if cfg.post_norm:
            f = L.rms_norm(f, p["post_ln2"])
        x = x + cfg.residual_scale * f
    return x, new_cache, aux


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype):
    kind = _effective_kind(cfg, kind)
    km = _mixer_kind(cfg, kind)
    if km == "mla":
        return L.init_mla_cache(cfg, batch, max_len, dtype)
    if km in ATTN_KINDS:
        return L.init_attention_cache(cfg, kind, batch, max_len, dtype)
    if km == "rec":
        return R.init_rglru_cache(cfg, batch, dtype)
    if km == "m":
        return R.init_mlstm_cache(cfg, batch, dtype)
    if km == "s":
        return R.init_slstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------

def param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_lm(key, cfg: ModelConfig):
    dtype = param_dtype(cfg)
    n_pat = len(cfg.block_pattern)
    keys = jax.random.split(key, n_pat + len(cfg.pattern_remainder) + 2)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_padded, cfg.d_model))
                  * 0.02).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], cfg.d_model,
                                         cfg.vocab_padded, dtype)
    reps = cfg.pattern_reps
    for pi, kind in enumerate(cfg.block_pattern):
        ks = jax.random.split(keys[2 + pi], reps)
        params[f"blocks_{pi}"] = jax.vmap(
            lambda k: init_block(k, cfg, kind, dtype))(ks)
    for ri, kind in enumerate(cfg.pattern_remainder):
        params[f"rem_{ri}"] = init_block(keys[2 + n_pat + ri], cfg, kind,
                                         dtype)
    return params


def _embed_in(params, cfg: ModelConfig, batch):
    if cfg.embedding_inputs:
        x = batch["embeds"].astype(param_dtype(cfg))
    else:
        x = params["embed"][batch["tokens"]]
    return x * cfg.scale_emb


def _logits_out(params, cfg: ModelConfig, x):
    x = L.rms_norm(x, params["final_norm"])
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = x @ head
    return L.softcap(logits.astype(jnp.float32), cfg.softcap_final)


def _default_positions(cfg: ModelConfig, B: int, S: int, offset=0):
    pos = jnp.arange(S) + offset
    pos = jnp.broadcast_to(pos[None], (B, S))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def forward(params, cfg: ModelConfig, batch, *, want_cache: bool = False,
            max_cache_len: Optional[int] = None, remat: bool = True):
    """Full-sequence forward (train / prefill).

    Returns (logits, cache, aux). ``cache`` is None unless want_cache.
    """
    x = _embed_in(params, cfg, batch)
    B, S = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, B, S)

    def block_fn(kind):
        def f(xa, bp):
            xx, aux_in = xa
            xx, c, aux = apply_block(bp, cfg, kind, xx, positions)
            return (xx, aux_in + aux), c
        return jax.checkpoint(f) if remat else f

    caches = {}
    aux = jnp.zeros((), jnp.float32)
    for pi, kind in enumerate(cfg.block_pattern):
        def scan_body(carry, bp, _kind=kind, _pi=pi):
            (xx, a), c = block_fn(_kind)(carry, bp[f"b{_pi}"])
            return (xx, a), c
        # pack: scan over a dict so each pattern position keeps its own tree
        stacked = {f"b{pi}": params[f"blocks_{pi}"]}
        (x, aux), cache_g = jax.lax.scan(scan_body, (x, aux), stacked)
        if want_cache:
            caches[f"g{pi}"] = cache_g
    for ri, kind in enumerate(cfg.pattern_remainder):
        (x, aux), c = block_fn(kind)((x, aux), params[f"rem_{ri}"])
        if want_cache:
            caches[f"r{ri}"] = c
    logits = _logits_out(params, cfg, x)
    return logits, (caches if want_cache else None), aux


def prefill_to_decode_cache(cfg: ModelConfig, cache, prefill_len: int,
                            max_len: int):
    """Convert forward(want_cache=True) output into decode_step layout.

    Full attention / MLA: pad the seq dim to max_len. Local (sliding
    window) attention: regroup the last W positions into the rolling
    buffer layout (slot = pos % W). Recurrent states pass through.
    """
    kinds = {f"g{pi}": kind for pi, kind in enumerate(cfg.block_pattern)}
    kinds.update({f"r{ri}": kind
                  for ri, kind in enumerate(cfg.pattern_remainder)})

    def grow(arr, seq_axis):
        if arr.shape[seq_axis] < max_len:
            pad = [(0, 0)] * arr.ndim
            pad[seq_axis] = (0, max_len - arr.shape[seq_axis])
            arr = jnp.pad(arr, pad)
        return arr

    def to_rolling(arr, seq_axis, W, P):
        idx = jnp.arange(W)
        src = idx + ((P - 1 - idx) // W) * W           # j == idx (mod W)
        src = jnp.clip(src, 0, P - 1)                  # invalid slots masked
        return jnp.take(arr, src, axis=seq_axis)       # by k_valid at decode

    new = {}
    for gname, c in cache.items():
        kind = _effective_kind(cfg, kinds[gname])
        seq_axis = 2 if gname.startswith("g") else 1   # leading scan-rep dim
        if isinstance(c, dict) and "k" in c:
            if kind == "local" and cfg.window and prefill_len > 0:
                W = min(cfg.window, max_len)
                new[gname] = {n: to_rolling(a, seq_axis, W, prefill_len)
                              for n, a in c.items()}
            else:
                new[gname] = {n: grow(a, seq_axis) for n, a in c.items()}
        elif isinstance(c, dict) and "ckv" in c:
            new[gname] = {n: grow(a, seq_axis) for n, a in c.items()}
        else:
            new[gname] = c
    return new


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    dtype = param_dtype(cfg)
    caches = {}
    for pi, kind in enumerate(cfg.block_pattern):
        single = init_block_cache(cfg, kind, batch_size, max_len, dtype)
        caches[f"g{pi}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.pattern_reps,) + a.shape
                                       ).copy(), single)
    for ri, kind in enumerate(cfg.pattern_remainder):
        caches[f"r{ri}"] = init_block_cache(cfg, kind, batch_size, max_len,
                                            dtype)
    return caches


def decode_step(params, cfg: ModelConfig, batch, cache, pos):
    """One-token decode. batch: tokens (B,1) or embeds (B,1,D); pos scalar."""
    x = _embed_in(params, cfg, batch)
    B = x.shape[0]
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, B, 1, offset=pos)
    new_cache = {}
    for pi, kind in enumerate(cfg.block_pattern):
        def scan_body(xx, bp_c, _kind=kind):
            bp, c = bp_c
            xx, newc, _ = apply_block(bp, cfg, _kind, xx, positions,
                                      cache=c, pos=pos)
            return xx, newc
        x, cache_g = jax.lax.scan(
            scan_body, x, (params[f"blocks_{pi}"], cache[f"g{pi}"]))
        new_cache[f"g{pi}"] = cache_g
    for ri, kind in enumerate(cfg.pattern_remainder):
        x, c, _ = apply_block(params[f"rem_{ri}"], cfg, kind, x, positions,
                              cache=cache[f"r{ri}"], pos=pos)
        new_cache[f"r{ri}"] = c
    logits = _logits_out(params, cfg, x)
    return logits, new_cache


# --------------------------------------------------------------------------
# losses (CE over padded vocab) + Task abstraction
# --------------------------------------------------------------------------

def cross_entropy(logits, labels, vocab_size: int):
    """logits (..., Vp) fp32, labels (...) int. Pad region masked out."""
    Vp = logits.shape[-1]
    if Vp > vocab_size:
        mask = jnp.arange(Vp) < vocab_size
        logits = jnp.where(mask, logits, L.MASK_VALUE)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def sample_labels(rng, logits, vocab_size: int):
    Vp = logits.shape[-1]
    if Vp > vocab_size:
        mask = jnp.arange(Vp) < vocab_size
        logits = jnp.where(mask, logits, L.MASK_VALUE)
    return jax.random.categorical(rng, logits, axis=-1)


class LMTask:
    """Bundles init/loss/sampled-loss for the federated engine."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        return init_lm(key, self.cfg)

    def logits(self, params, batch):
        logits, _, aux = forward(params, self.cfg, batch,
                                 remat=self.cfg.train_remat)
        return logits, aux

    def loss(self, params, batch, rng=None):
        logits, aux = self.logits(params, batch)
        return cross_entropy(logits, batch["labels"], self.cfg.vocab_size) + aux

    def sampled_loss(self, params, batch, rng):
        """GNB inner loss: CE against labels sampled from the model itself."""
        logits, aux = self.logits(params, batch)
        y = sample_labels(rng, jax.lax.stop_gradient(logits),
                          self.cfg.vocab_size)
        return cross_entropy(logits, y, self.cfg.vocab_size) + aux

    def gnb_batch_size(self, batch) -> int:
        lab = batch["labels"]
        return int(lab.shape[0] * lab.shape[1]) if lab.ndim > 1 else int(lab.shape[0])
