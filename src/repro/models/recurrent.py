"""Recurrent sequence-mixing blocks: RG-LRU (Griffin/RecurrentGemma),
mLSTM and sLSTM (xLSTM).

TPU adaptation notes (see DESIGN.md §3):
  * RG-LRU uses ``lax.associative_scan`` (log-depth, elementwise diagonal
    recurrence) instead of the GPU kernel of the Griffin paper.
  * mLSTM uses the chunkwise-parallel form — intra-chunk attention-style
    matmuls (MXU-friendly) + inter-chunk ``lax.scan`` over the matrix
    memory. Validated against a step-by-step scan oracle in tests.
  * sLSTM has no parallel form (nonlinear recurrence) — ``lax.scan``.
All blocks share the attention-block interface:
    apply(p, cfg, x, positions, cache=None, pos=None) -> (out, new_cache)
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

RG_LRU_C = 8.0
MLSTM_CHUNK = 128


def _replicate_tail(x, keep: int = 1):
    """Pin all dims but the first `keep` to replicated — forces GSPMD to
    reshard HERE (on this dtype) instead of after a later fp32 convert.
    No-op outside a mesh context (single-device tests)."""
    am = jax.sharding.get_abstract_mesh()
    if am is None or not am.axis_names:
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            from jax.interpreters import pxla       # legacy `with mesh:`
            lm = pxla.thread_resources.env.physical_mesh
        if lm is None or lm.empty:
            return x
    P = jax.sharding.PartitionSpec
    spec = P(*([P.UNCONSTRAINED] * keep + [None] * (x.ndim - keep)))
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------------
# causal depthwise temporal conv (shared by RG-LRU and mLSTM blocks)
# --------------------------------------------------------------------------

def causal_conv1d(u, w, conv_state=None):
    """u (B,S,W), w (cw,W) depthwise. Returns (out, new_state (B,cw-1,W))."""
    cw = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    padded = jnp.concatenate([conv_state, u], axis=1)
    out = sum(padded[:, i:i + u.shape[1], :] * w[i] for i in range(cw))
    return out, padded[:, -(cw - 1):, :]


# --------------------------------------------------------------------------
# RG-LRU block
# --------------------------------------------------------------------------

def init_rglru(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    W = cfg.lru_width or D
    ks = jax.random.split(key, 7)
    # Lambda init so that a = exp(-c*softplus(L)) lands in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (W,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * RG_LRU_C)))
    return {
        "w_in": dense_init(ks[1], D, W, dtype),
        "w_gate_in": dense_init(ks[2], D, W, dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, W)) * 0.1).astype(dtype),
        "w_a": dense_init(ks[4], W, W, dtype),
        "b_a": jnp.zeros((W,), dtype),
        "w_x": dense_init(ks[5], W, W, dtype),
        "b_x": jnp.zeros((W,), dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[6], W, D, dtype),
    }


def _rglru_gates(p, u):
    """u (B,S,W) -> (log_a, scaled_input) in fp32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["w_x"].astype(jnp.float32) + p["b_x"].astype(jnp.float32))
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"]) * r
    scaled = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * uf)
    return log_a, scaled


def rglru_apply(p, cfg: ModelConfig, x, positions, *, cache=None, pos=None):
    B, S, D = x.shape
    gate = jax.nn.gelu(x @ p["w_gate_in"], approximate=True)
    u = x @ p["w_in"]
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = causal_conv1d(u, p["conv_w"], conv_state)
    log_a, scaled = _rglru_gates(p, u)

    if cache is None:
        a = jnp.exp(log_a)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        _, h = jax.lax.associative_scan(combine, (a, scaled), axis=1)
        h_last = h[:, -1, :]
    else:
        h_prev = cache["state"]
        h = jnp.exp(log_a) * h_prev[:, None, :] + scaled
        h_last = h[:, -1, :]
    out = ((h.astype(x.dtype) * gate) @ p["w_out"])
    return out, {"state": h_last, "conv": new_conv}


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype):
    W = cfg.lru_width or cfg.d_model
    return {"state": jnp.zeros((batch, W), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, W), dtype)}


# --------------------------------------------------------------------------
# mLSTM block (chunkwise-parallel matrix memory)
# --------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    inner = int(cfg.mlstm_proj_factor * D)
    H = cfg.num_heads
    assert inner % H == 0
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], D, inner, dtype),
        "w_up_gate": dense_init(ks[1], D, inner, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, inner)) * 0.1).astype(dtype),
        "wq": dense_init(ks[3], inner, inner, dtype),
        "wk": dense_init(ks[4], inner, inner, dtype),
        "wv": dense_init(ks[5], inner, inner, dtype),
        "w_if": dense_init(ks[6], inner, 2 * H, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "w_down": dense_init(ks[7], inner, D, dtype),
    }


def _mlstm_chunk_scan(q, k, v, li, lf, state=None,
                      cdt=jnp.float32):
    """Chunkwise stabilized mLSTM recurrence.

    q,k,v: (B,H,S,dh) with k pre-scaled by 1/sqrt(dh).
    li, lf: (B,H,S) log input/forget gates (fp32).
    state: optional (C (B,H,dk,dv), n (B,H,dk), m (B,H)) — stabilized.
    cdt: chunk-operand dtype — bf16 keeps q/k/v bf16 across the model-axis
    resharding boundary (halves gather bytes); einsums accumulate fp32 via
    preferred_element_type. Carries (C, n, m) are always fp32.
    Returns (h (B,H,S,dh), new_state).
    """
    B, H, S, dh = q.shape
    L = min(MLSTM_CHUNK, S)
    assert S % L == 0, "sequence must be divisible by mLSTM chunk"
    nc = S // L

    def rs(t):
        return t.reshape(B, H, nc, L, -1).swapaxes(0, 2).swapaxes(1, 2) \
            if t.ndim == 4 else t.reshape(B, H, nc, L).swapaxes(0, 2).swapaxes(1, 2)
    # -> (nc, B, H, L, dh) / (nc, B, H, L)
    qc, kc, vc = rs(q), rs(k), rs(v)
    lic, lfc = rs(li), rs(lf)

    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def body(carry, xs):
        C, n, m = carry                       # stabilized: C~ = C * e^{-m}
        qb, kb, vb, lib, lfb = xs             # (B,H,L,dh)/(B,H,L)
        b = jnp.cumsum(lfb, axis=-1)          # inclusive cumsum of log-f
        # intra-chunk log decay matrix D_ij = b_i - lf_i... careful:
        # decay from j to i (j<=i) = sum_{s=j+1..i} lf_s = b_i - b_j
        Dm = b[..., :, None] - b[..., None, :] + lib[..., None, :]
        mask = jnp.tril(jnp.ones((L, L), bool))
        Dm = jnp.where(mask, Dm, -jnp.inf)
        inter_log = m[..., None] + b          # (B,H,L) decay of carry-in
        m_i = jnp.maximum(jnp.max(Dm, axis=-1), inter_log)   # (B,H,L)
        W = jnp.exp(Dm - m_i[..., None])                     # (B,H,L,L)
        qb, kb, vb = qb.astype(cdt), kb.astype(cdt), vb.astype(cdt)
        qk = jnp.einsum("bhid,bhjd->bhij", qb, kb,
                        preferred_element_type=jnp.float32)
        intra_num = jnp.einsum("bhij,bhjd->bhid", (W * qk).astype(cdt), vb,
                               preferred_element_type=jnp.float32)
        intra_den = jnp.einsum("bhij,bhij->bhi", W, qk)
        w_inter = jnp.exp(inter_log - m_i)                   # (B,H,L)
        # C/n readout in cdt (carry itself stays fp32; fp32 accumulation)
        inter_num = jnp.einsum("bhid,bhde->bhie", qb, C.astype(cdt),
                               preferred_element_type=jnp.float32) \
            * w_inter[..., None]
        inter_den = jnp.einsum("bhid,bhd->bhi", qb, n.astype(cdt),
                               preferred_element_type=jnp.float32) \
            * w_inter
        num = intra_num + inter_num
        den = jnp.maximum(jnp.abs(intra_den + inter_den), jnp.exp(-m_i))
        h = num / den[..., None]
        # carry update to chunk end
        btot = b[..., -1]                                    # (B,H)
        m_new = jnp.maximum(m + btot,
                            jnp.max(btot[..., None] - b + lib, axis=-1))
        w_kv = jnp.exp(btot[..., None] - b + lib - m_new[..., None])
        C_new = C * jnp.exp(m + btot - m_new)[..., None, None] + jnp.einsum(
            "bhj,bhjd,bhje->bhde", w_kv.astype(cdt), kb, vb,
            preferred_element_type=jnp.float32)
        n_new = n * jnp.exp(m + btot - m_new)[..., None] + jnp.einsum(
            "bhj,bhjd->bhd", w_kv.astype(cdt), kb,
            preferred_element_type=jnp.float32)
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    h = hs.swapaxes(1, 2).swapaxes(0, 2).reshape(B, H, S, dh)
    return h, (C, n, m)


def mlstm_apply(p, cfg: ModelConfig, x, positions, *, cache=None, pos=None):
    B, S, D = x.shape
    H = cfg.num_heads
    inner = int(cfg.mlstm_proj_factor * D)
    dh = inner // H
    z = x @ p["w_up"]
    og = jax.nn.silu(x @ p["w_up_gate"])
    conv_state = cache["conv"] if cache is not None else None
    zc, new_conv = causal_conv1d(z, p["conv_w"], conv_state)
    zc = jax.nn.silu(zc)
    q = (zc @ p["wq"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    k = (zc @ p["wk"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3) / math.sqrt(dh)
    v = (z @ p["wv"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    gates = zc.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    li = gates[..., :H].transpose(0, 2, 1)            # (B,H,S) exp input gate
    lf = jax.nn.log_sigmoid(gates[..., H:]).transpose(0, 2, 1)

    if cache is None:
        cdt = jnp.dtype(cfg.scan_compute_dtype)
        h, state = _mlstm_chunk_scan(q, k, v, li, lf, cdt=cdt)
    else:
        # single-step recurrent update (S == 1)
        C, n, m = cache["C"], cache["n"], cache["m"]
        li0, lf0 = li[..., 0], lf[..., 0]
        m_new = jnp.maximum(lf0 + m, li0)
        fp = jnp.exp(lf0 + m - m_new)
        ip = jnp.exp(li0 - m_new)
        k0 = k[..., 0, :].astype(jnp.float32)
        v0 = v[..., 0, :].astype(jnp.float32)
        q0 = q[..., 0, :].astype(jnp.float32)
        C = fp[..., None, None] * C + ip[..., None, None] * (
            k0[..., :, None] * v0[..., None, :])
        n = fp[..., None] * n + ip[..., None] * k0
        num = jnp.einsum("bhd,bhde->bhe", q0, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q0, n)),
                          jnp.exp(-m_new))
        h = (num / den[..., None])[:, :, None, :]                # (B,H,1,dh)
        state = (C, n, m_new)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, inner).astype(x.dtype)
    out = (h * og) @ p["w_down"]
    return out, {"C": state[0], "n": state[1], "m": state[2], "conv": new_conv}


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype):
    H = cfg.num_heads
    inner = int(cfg.mlstm_proj_factor * cfg.d_model)
    dh = inner // H
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, inner), dtype)}


# --------------------------------------------------------------------------
# sLSTM block (strictly sequential nonlinear recurrence -> lax.scan)
# --------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    H = cfg.num_heads
    dh = D // H
    ks = jax.random.split(key, 7)
    d_up = int(cfg.slstm_proj_factor * D)
    return {
        "w_gates": dense_init(ks[0], D, 4 * D, dtype),     # z,i,f,o
        "b_gates": jnp.concatenate(
            [jnp.zeros((2 * D,)), 3.0 * jnp.ones((D,)), jnp.zeros((D,))]
        ).astype(jnp.float32),
        "r_gates": (jax.random.normal(ks[1], (4, H, dh, dh)) / math.sqrt(dh)
                    ).astype(dtype),
        "gn": jnp.ones((D,), dtype),
        "w_up": dense_init(ks[2], D, d_up, dtype),
        "w_up_gate": dense_init(ks[3], D, d_up, dtype),
        "w_down": dense_init(ks[4], d_up, D, dtype),
    }


def _slstm_step(p, H, dh, carry, wx):
    """carry: (h,c,n,m) each (B,H,dh). wx: (B,4D) precomputed W x + b."""
    h, c, n, m = carry
    B = h.shape[0]
    D = H * dh
    rg = p["r_gates"].astype(jnp.float32)
    rh = jnp.einsum("bhd,ghde->gbhe", h, rg)          # (4,B,H,dh)
    wz, wi, wf, wo = [wx[:, i * D:(i + 1) * D].reshape(B, H, dh)
                      for i in range(4)]
    z = jnp.tanh(wz + rh[0])
    it = wi + rh[1]
    ft = wf + rh[2]
    o = jax.nn.sigmoid(wo + rh[3])
    m_new = jnp.maximum(ft + m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(ft + m - m_new)
    c = fp * c + ip * z
    n = fp * n + ip
    h = o * c / jnp.maximum(n, 1e-6)
    return (h, c, n, m_new)


def slstm_apply(p, cfg: ModelConfig, x, positions, *, cache=None, pos=None):
    B, S, D = x.shape
    H = cfg.num_heads
    dh = D // H
    wx = x.astype(jnp.float32) @ p["w_gates"].astype(jnp.float32) + p["b_gates"]

    if cache is None:
        carry = tuple(jnp.zeros((B, H, dh), jnp.float32) for _ in range(3)) + (
            jnp.full((B, H, dh), -1e30, jnp.float32),)
        carry = (carry[0], carry[1], carry[2], carry[3])

        def step(carry, wx_t):
            new = _slstm_step(p, H, dh, carry, wx_t)
            return new, new[0]

        carry, hs = jax.lax.scan(step, carry, wx.swapaxes(0, 1),
                                 unroll=max(1, cfg.slstm_unroll))
        h_seq = hs.swapaxes(0, 1).reshape(B, S, D)
    else:
        carry = (cache["h"], cache["c"], cache["n"], cache["m"])
        carry = _slstm_step(p, H, dh, carry, wx[:, 0])
        h_seq = carry[0].reshape(B, 1, D)

    from repro.models.layers import rms_norm
    h_seq = rms_norm(h_seq.astype(x.dtype), p["gn"])
    up = jax.nn.gelu(h_seq @ p["w_up"], approximate=True) * (h_seq @ p["w_up_gate"])
    out = up @ p["w_down"]
    new_cache = {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}
    return out, new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype):
    H = cfg.num_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"h": z, "c": z, "n": z,
            "m": jnp.full((batch, H, dh), -1e30, jnp.float32)}
