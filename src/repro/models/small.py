"""The paper's evaluation models: MLP and CNN image classifiers
(MNIST/FMNIST-shaped inputs 28x28x1, 10 classes).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

NUM_CLASSES = 10


class MLPTask:
    """784 -> hidden -> hidden -> 10, ReLU (paper's MLP)."""

    def __init__(self, hidden: int = 128, num_classes: int = NUM_CLASSES):
        self.hidden = hidden
        self.num_classes = num_classes

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w1": dense_init(k1, 784, self.hidden),
            "b1": jnp.zeros((self.hidden,)),
            "w2": dense_init(k2, self.hidden, self.hidden),
            "b2": jnp.zeros((self.hidden,)),
            "w3": dense_init(k3, self.hidden, self.num_classes),
            "b3": jnp.zeros((self.num_classes,)),
        }

    def logits(self, params, batch):
        x = batch["x"].reshape(batch["x"].shape[0], -1)
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        h = jax.nn.relu(h @ params["w2"] + params["b2"])
        return h @ params["w3"] + params["b3"]

    def loss(self, params, batch, rng=None):
        return _ce(self.logits(params, batch), batch["y"])

    def sampled_loss(self, params, batch, rng):
        logits = self.logits(params, batch)
        y = jax.random.categorical(rng, jax.lax.stop_gradient(logits), axis=-1)
        return _ce(logits, y)

    def accuracy(self, params, batch):
        return jnp.mean(
            jnp.argmax(self.logits(params, batch), -1) == batch["y"])

    def gnb_batch_size(self, batch) -> int:
        return int(batch["y"].shape[0])


class CNNTask:
    """2x (conv3x3 + relu + maxpool2) -> fc (paper's CNN)."""

    def __init__(self, channels: Tuple[int, int] = (16, 32),
                 num_classes: int = NUM_CLASSES):
        self.channels = channels
        self.num_classes = num_classes

    def init(self, key):
        c1, c2 = self.channels
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "conv1": jax.random.normal(k1, (3, 3, 1, c1)) / math.sqrt(9),
            "bc1": jnp.zeros((c1,)),
            "conv2": jax.random.normal(k2, (3, 3, c1, c2)) / math.sqrt(9 * c1),
            "bc2": jnp.zeros((c2,)),
            "fc": dense_init(k3, 7 * 7 * c2, self.num_classes),
            "bfc": jnp.zeros((self.num_classes,)),
        }

    def logits(self, params, batch):
        x = batch["x"]
        if x.ndim == 3:
            x = x[..., None]
        for w, b in ((params["conv1"], params["bc1"]),
                     (params["conv2"], params["bc2"])):
            x = jax.lax.conv_general_dilated(
                x, w, window_strides=(1, 1), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x + b)
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        x = x.reshape(x.shape[0], -1)
        return x @ params["fc"] + params["bfc"]

    loss = MLPTask.loss
    sampled_loss = MLPTask.sampled_loss
    accuracy = MLPTask.accuracy
    gnb_batch_size = MLPTask.gnb_batch_size


def _ce(logits, labels):
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)
