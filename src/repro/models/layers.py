"""Shared neural-net primitives for the model zoo.

Everything is functional: ``init_*`` builds a param pytree (nested dicts of
jnp arrays), ``*_apply`` consumes it. Layouts: activations (B, S, D);
attention tensors (B, S, H, hd).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# Neg-inf substitute that is safe in bf16 softmax arithmetic.
MASK_VALUE = -1e9

# Materialised attention scores above this seq length use the chunked
# online-softmax path (memory: O(S * KV_CHUNK) instead of O(S^2)).
CHUNK_ATTN_THRESHOLD = 2048
KV_CHUNK = 1024


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def stacked_dense_init(key, n: int, d_in: int, d_out: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (n, d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


# --------------------------------------------------------------------------
# norms / activations
# --------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def ffn_act(kind: str, gate, up):
    if kind == "swiglu":
        return jax.nn.silu(gate) * up
    if kind == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    if kind == "gelu":
        return jax.nn.gelu(gate, approximate=True)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# RoPE (standard / partial / M-RoPE)
# --------------------------------------------------------------------------

def _rope_sin_cos(positions, rot_dim: int, theta: float):
    """positions (...,) -> sin/cos (..., rot_dim//2) in fp32."""
    half = rot_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x, positions, cfg: ModelConfig, rot_dim: Optional[int] = None):
    """x: (B, S, H, hd). positions: (B, S) or (3, B, S) for M-RoPE."""
    hd = x.shape[-1]
    if rot_dim is None:
        rot_dim = int(hd * cfg.rotary_pct)
        rot_dim -= rot_dim % 2
    half = rot_dim // 2

    if cfg.mrope_sections is not None and positions.ndim == 3:
        # M-RoPE: the rot_dim/2 frequency slots are split into (t, h, w)
        # sections, each reading its own position channel.
        sins, coss = [], []
        start = 0
        for sec, pos_c in zip(cfg.mrope_sections, positions):
            freqs_idx = jnp.arange(start, start + sec, dtype=jnp.float32)
            inv = 1.0 / (cfg.rope_theta ** (freqs_idx / half))
            ang = pos_c.astype(jnp.float32)[..., None] * inv  # (B,S,sec)
            sins.append(jnp.sin(ang))
            coss.append(jnp.cos(ang))
            start += sec
        sin = jnp.concatenate(sins, axis=-1)[:, :, None, :]
        cos = jnp.concatenate(coss, axis=-1)[:, :, None, :]
    else:
        if positions.ndim == 3:          # collapse M-RoPE channels (text-only)
            positions = positions[0]
        sin, cos = _rope_sin_cos(positions, rot_dim, cfg.rope_theta)
        sin, cos = sin[:, :, None, :], cos[:, :, None, :]

    rot, rest = x[..., :rot_dim], x[..., rot_dim:]
    r1, r2 = rot[..., :half], rot[..., half:]
    r1f, r2f = r1.astype(jnp.float32), r2.astype(jnp.float32)
    out = jnp.concatenate(
        [r1f * cos - r2f * sin, r2f * cos + r1f * sin], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, rest], axis=-1) if rest.shape[-1] else out


# --------------------------------------------------------------------------
# attention cores
# --------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q (B,Sq,H,hd), k (B,Sk,K,hd) -> scores (B,H,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    q = q.reshape(B, Sq, K, H // K, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k)
    return s.reshape(B, H, Sq, k.shape[1])


def _gqa_out(probs, v, out_dtype=None):
    """probs (B,H,Sq,Sk), v (B,Sk,K,hd) -> (B,Sq,H,hd)."""
    B, H, Sq, Sk = probs.shape
    K = v.shape[2]
    p = probs.reshape(B, K, H // K, Sq, Sk)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v,
                   preferred_element_type=out_dtype)
    return o.reshape(B, Sq, H, v.shape[-1])


def attn_mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int],
                   k_valid=None):
    """Additive bias (…, Sq, Sk) in fp32."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    if k_valid is not None:
        ok &= k_valid[None, :]
    return jnp.where(ok, 0.0, MASK_VALUE).astype(jnp.float32)


def attention_dense(q, k, v, bias, scale: float, softcap_val=None):
    """Reference full-materialisation attention. bias (Sq,Sk) or (B,1,Sq,Sk)."""
    s = _gqa_scores(q, k).astype(jnp.float32) * scale
    s = softcap(s, softcap_val)
    if bias.ndim == 2:
        bias = bias[None, None]
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p.astype(v.dtype), v)


def attention_chunked(q, k, v, *, q_pos, k_pos, causal, window,
                      scale, softcap_val=None, k_valid=None,
                      kv_chunk: int = KV_CHUNK):
    """Online-softmax attention, scanning KV in chunks.

    Memory is O(Sq * kv_chunk) per head instead of O(Sq * Sk). Pure JAX
    (differentiable); the Pallas flash kernel in repro/kernels mirrors it.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    n_chunks = -(-Sk // kv_chunk)
    pad = n_chunks * kv_chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-1)
        kv_ok = jnp.pad(
            k_valid if k_valid is not None else jnp.ones((Sk,), bool),
            (0, pad), constant_values=False)
    else:
        kv_ok = k_valid if k_valid is not None else jnp.ones((Sk,), bool)

    kc = k.reshape(B, n_chunks, kv_chunk, k.shape[2], hd)
    vc = v.reshape(B, n_chunks, kv_chunk, v.shape[2], v.shape[-1])
    kpc = k_pos.reshape(n_chunks, kv_chunk)
    kokc = kv_ok.reshape(n_chunks, kv_chunk)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, kp, kok = xs
        s = _gqa_scores(q, kb).astype(jnp.float32) * scale  # (B,H,Sq,ck)
        s = softcap(s, softcap_val)
        s = s + attn_mask_bias(q_pos, kp, causal=causal, window=window,
                               k_valid=kok)[None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = _gqa_out(p.astype(jnp.float32), vb.astype(jnp.float32))
        acc = acc * corr[..., None] + pv.transpose(0, 2, 1, 3)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    acc0 = jnp.zeros((B, H, Sq, v.shape[-1]), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), kpc, kokc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,hd)


def attention(q, k, v, *, q_pos, k_pos, causal, window=None, scale=None,
              softcap_val=None, k_valid=None, chunk_threshold=None,
              kv_chunk=None):
    """Dispatch between dense and chunked attention."""
    if scale is None:
        scale = 1.0 / math.sqrt(q.shape[-1])
    if chunk_threshold is None:
        chunk_threshold = CHUNK_ATTN_THRESHOLD
    Sq, Sk = q.shape[1], k.shape[1]
    if max(Sq, Sk) > chunk_threshold and Sq > 1:
        return attention_chunked(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                 causal=causal, window=window, scale=scale,
                                 softcap_val=softcap_val, k_valid=k_valid,
                                 kv_chunk=kv_chunk or KV_CHUNK)
    bias = attn_mask_bias(q_pos, k_pos, causal=causal, window=window,
                          k_valid=k_valid)
    return attention_dense(q, k, v, bias, scale, softcap_val)


# --------------------------------------------------------------------------
# GQA attention block (covers attn / local / global kinds)
# --------------------------------------------------------------------------

def pad_head_mask(cfg: ModelConfig):
    """Bool (Hp*hd,) — True where the flattened q/o dim holds a REAL head.

    Padded heads are interleaved at the END OF EACH KV GROUP (not the tail
    of the tensor): real q-head j of kv-group j//g_old must land in slot
    (j//g_old)*g_new + j%g_old so the GQA pairing is preserved. Requires
    GQA with Hp divisible by num_kv_heads."""
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    Hp = max(cfg.pad_attn_heads, H)
    assert K < H and Hp % K == 0, (
        "pad_attn_heads requires GQA (K < H) and padded count divisible "
        f"by kv heads; got H={H} K={K} Hp={Hp}")
    g_old, g_new = H // K, Hp // K
    real = (jnp.arange(Hp) % g_new) < g_old
    return jnp.repeat(real, hd)


def init_attention(key, cfg: ModelConfig, dtype):
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    Hp = max(cfg.pad_attn_heads, H) if cfg.pad_attn_heads else H
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, Hp * hd, dtype),
        "wk": dense_init(ks[1], D, K * hd, dtype),
        "wv": dense_init(ks[2], D, K * hd, dtype),
        "wo": dense_init(ks[3], Hp * hd, D, dtype),
    }
    if Hp != H:
        # zero the padded head columns/rows: exact no-op heads (zero
        # output contribution, zero gradient, zeros preserved by
        # decay/clip updates); group-interleaved so real heads keep
        # their kv pairing
        col = pad_head_mask(cfg).astype(dtype)
        p["wq"] = p["wq"] * col[None, :]
        p["wo"] = p["wo"] * col[:, None]
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention_apply(p, cfg: ModelConfig, x, positions, *, kind: str,
                    cache=None, pos=None):
    """x (B,S,D). Full-seq if cache is None, else single-token decode.

    Returns (out, new_cache). new_cache is a dict {"k","v"} (rolling window
    buffers for 'local' kind).
    """
    B, S, D = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.pad_attn_heads:
        H = max(cfg.pad_attn_heads, H)      # zero no-op heads (see init)
    window = cfg.window if kind == "local" else None
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, K, hd)
    v = (x @ p["wv"]).reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)

    if cache is None:
        q_pos = positions[0] if positions.ndim == 3 else positions
        q_pos = q_pos[0] if q_pos.ndim == 2 else q_pos  # (S,)
        out = attention(q, k, v, q_pos=q_pos, k_pos=q_pos,
                        causal=cfg.causal, window=window,
                        softcap_val=cfg.softcap_attn,
                        chunk_threshold=cfg.attn_chunk_threshold,
                        kv_chunk=cfg.attn_kv_chunk)
        if cfg.pad_attn_heads:
            # zero the padded heads' outputs: their uniform-softmax PV is
            # nonzero, and without this the zero wo ROWS would still
            # receive gradient (out^T dY) and drift away from zero
            out = out * pad_head_mask(cfg).reshape(H, hd).astype(out.dtype)
        new_cache = {"k": k, "v": v}
    else:
        # decode: S == 1, pos is the absolute position of this token.
        ck, cv = cache["k"], cache["v"]
        W = ck.shape[1]
        slot = pos % W if window is not None else jnp.minimum(pos, W - 1)
        ck = jax.lax.dynamic_update_slice(ck, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, slot, 0, 0))
        if window is not None:
            # rolling buffer: absolute positions of the W slots
            base = pos - (W - 1)
            idx = jnp.arange(W)
            k_pos = jnp.where(idx <= slot, pos - (slot - idx),
                              pos - (slot - idx) - W)
            k_valid = k_pos >= 0
        else:
            k_pos = jnp.arange(W)
            k_valid = k_pos <= pos
        out = attention(q, ck, cv, q_pos=pos[None], k_pos=k_pos,
                        causal=False, window=None,
                        softcap_val=cfg.softcap_attn, k_valid=k_valid)
        if cfg.pad_attn_heads:
            out = out * pad_head_mask(cfg).reshape(H, hd).astype(out.dtype)
        new_cache = {"k": ck, "v": cv}
    out = out.reshape(B, S, H * hd) @ p["wo"]
    return out, new_cache


def init_attention_cache(cfg: ModelConfig, kind: str, batch: int,
                         max_len: int, dtype):
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L = min(cfg.window, max_len) if kind == "local" and cfg.window else max_len
    return {"k": jnp.zeros((batch, L, K, hd), dtype),
            "v": jnp.zeros((batch, L, K, hd), dtype)}


# --------------------------------------------------------------------------
# MLA attention (DeepSeek-V2): latent-compressed KV cache
# --------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype):
    m = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], D, H * qk_dim, dtype),
        "w_dkv": dense_init(ks[1], D, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_ukv": dense_init(ks[2], m.kv_lora_rank,
                            H * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": dense_init(ks[3], H * m.v_head_dim, D, dtype),
    }


def _mla_kv(p, cfg, ckv_norm, kpe, H):
    """Up-project latent -> per-head k, v. ckv_norm (B,S,rank), kpe (B,S,rd)."""
    m = cfg.mla
    B, S = ckv_norm.shape[:2]
    kv = (ckv_norm @ p["w_ukv"]).reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = kv[..., :m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]
    k_pe = jnp.broadcast_to(kpe[:, :, None, :], (B, S, H, m.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, k_pe], axis=-1)
    return k, v


def mla_apply(p, cfg: ModelConfig, x, positions, *, cache=None, pos=None):
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, qk_dim)
    q_nope, q_pe = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_pe = apply_rope(q_pe, positions, cfg, rot_dim=m.qk_rope_head_dim)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)

    dkv = x @ p["w_dkv"]
    ckv, kpe = dkv[..., :m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
    ckv = rms_norm(ckv, p["kv_norm"])
    kpe = apply_rope(kpe[:, :, None, :], positions, cfg,
                     rot_dim=m.qk_rope_head_dim)[:, :, 0, :]

    scale = 1.0 / math.sqrt(qk_dim)
    if cache is None:
        k, v = _mla_kv(p, cfg, ckv, kpe, H)
        q_pos = positions[0] if positions.ndim == 3 else positions
        q_pos = q_pos[0] if q_pos.ndim == 2 else q_pos
        out = attention(q, k, v, q_pos=q_pos, k_pos=q_pos, causal=cfg.causal,
                        scale=scale)
        new_cache = {"ckv": ckv, "kpe": kpe}
    else:
        cckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, pos, 0))
        ckpe = jax.lax.dynamic_update_slice(cache["kpe"], kpe, (0, pos, 0))
        Sc = cckv.shape[1]
        k, v = _mla_kv(p, cfg, cckv, ckpe, H)   # up-project on the fly
        k_pos = jnp.arange(Sc)
        out = attention(q, k, v, q_pos=pos[None], k_pos=k_pos, causal=False,
                        scale=scale, k_valid=k_pos <= pos)
        new_cache = {"ckv": cckv, "kpe": ckpe}
    out = out.reshape(B, S, H * m.v_head_dim) @ p["wo"]
    return out, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {"ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "kpe": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)}


# --------------------------------------------------------------------------
# dense FFN + MoE
# --------------------------------------------------------------------------

def init_ffn(key, d_model: int, d_ff: int, kind: str, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[1], d_model, d_ff, dtype),
         "w_down": dense_init(ks[2], d_ff, d_model, dtype)}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[0], d_model, d_ff, dtype)
    return p


def ffn_apply(p, kind: str, x):
    gate = x @ p["w_gate"] if "w_gate" in p else None
    up = x @ p["w_up"]
    return ffn_act(kind, gate if gate is not None else up, up) @ p["w_down"]


def init_moe(key, cfg: ModelConfig, dtype):
    mo = cfg.moe
    D, E, F = cfg.d_model, mo.num_experts, mo.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "w_gate": stacked_dense_init(ks[1], E, D, F, dtype),
        "w_up": stacked_dense_init(ks[2], E, D, F, dtype),
        "w_down": stacked_dense_init(ks[3], E, F, D, dtype),
    }
    if mo.num_shared:
        p["shared"] = init_ffn(ks[4], D, mo.num_shared * mo.d_ff_shared,
                               cfg.ffn_kind, dtype)
    return p


def moe_apply(p, cfg: ModelConfig, x):
    """Capacity-based top-k routing with one-hot dispatch einsums.

    The (B,S,E,C) dispatch/combine tensors shard B->data, E->model; GSPMD
    turns the token->expert regrouping into the all-to-all of classic
    expert parallelism. Returns (out, aux_loss).
    """
    mo = cfg.moe
    B, S, D = x.shape
    E, K = mo.num_experts, mo.top_k
    C = max(int(S * K / E * mo.capacity_factor), 1)

    logits = (x.astype(jnp.float32) @ p["router"])          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position-in-expert bookkeeping, processed selection-by-selection
    combine = jnp.zeros((B, S, E, C), jnp.float32)
    fill = jnp.zeros((B, E), jnp.float32)                    # tokens per expert
    for kk in range(K):
        mask_k = jax.nn.one_hot(expert_idx[:, :, kk], E)     # (B,S,E)
        pos_in_e = jnp.cumsum(mask_k, axis=1) - mask_k + fill[:, None, :]
        keep = (pos_in_e < C) * mask_k
        slot = jax.nn.one_hot(pos_in_e.astype(jnp.int32), C) # (B,S,E,C)
        combine = combine + (gate_vals[:, :, kk, None, None]
                             * keep[..., None] * slot)
        fill = fill + jnp.sum(mask_k, axis=1)
    dispatch = (combine > 0).astype(x.dtype)

    xin = jnp.einsum("bsec,bsd->ebcd", dispatch, x)          # (E,B,C,D)
    h_gate = jnp.einsum("ebcd,edf->ebcf", xin, p["w_gate"])
    h_up = jnp.einsum("ebcd,edf->ebcf", xin, p["w_up"])
    h = ffn_act(cfg.ffn_kind, h_gate, h_up)
    eout = jnp.einsum("ebcf,efd->ebcd", h, p["w_down"])
    out = jnp.einsum("bsec,ebcd->bsd", combine.astype(x.dtype), eout)

    if mo.num_shared:
        out = out + ffn_apply(p["shared"], cfg.ffn_kind, x)

    # Switch-style load-balance auxiliary loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx, E).sum(axis=2), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = mo.aux_loss_coef * E * jnp.sum(frac_tokens * frac_probs)
    return out, aux
