"""Byzantine fault injection over the packed wire substrate.

Membership is *deterministic config arithmetic*: the byzantine and
label-noise subsets are drawn host-side from ``RobustConfig.seed``
(independent streams), so a run replays bit-for-bit and tests can
recompute the masks.  The masks are static numpy constants folded
into the jitted round/dispatch — with ``attack="none"`` (or an empty
mask) callers skip `attack_wires` entirely and the traced graph is
unchanged.

Wire attacks transform a malicious client's *encoded uplink buffer*
(the packed (rows, cols) fp32 payload the server would decode), never
its local training: geometry, dtype and headers are preserved
(pinned by tests/test_property.py).  In the engine's direct path the
same transforms apply in delta space (contribution minus the round-
start model) — equivalent semantics on an uncompressed wire.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTACKS

#: fold_in salt separating the random-wire attack stream from every
#: other per-round consumer of the round rng
ATTACK_SALT = 0xB12A


def _subset_mask(seed_stream, fraction: float,
                 num_clients: int) -> np.ndarray:
    n = int(round(fraction * num_clients))
    n = max(0, min(num_clients, n))
    mask = np.zeros(num_clients, dtype=bool)
    if n:
        rng = np.random.default_rng(seed_stream)
        mask[rng.permutation(num_clients)[:n]] = True
    return mask


def byzantine_mask(robust, num_clients: int) -> np.ndarray:
    """(C,) bool: which clients mount the configured wire attack.
    Deterministic per ``robust.seed``; all-False when disabled."""
    if robust.attack not in ATTACKS:
        raise ValueError(
            f"unknown attack {robust.attack!r} (want one of {ATTACKS})")
    if robust.attack == "none":
        return np.zeros(num_clients, dtype=bool)
    return _subset_mask([robust.seed, 0], robust.attack_fraction,
                        num_clients)


def label_noise_mask(robust, num_clients: int) -> np.ndarray:
    """(C,) bool: which clients train on noisy labels (independent of
    the byzantine subset; deterministic per ``robust.seed``)."""
    return _subset_mask([robust.seed, 1], robust.label_noise_fraction,
                        num_clients)


def wire_attack_active(robust, num_clients: int) -> bool:
    """True iff `attack_wires` would change anything — callers gate on
    this so the benign graph never contains attack ops."""
    return (robust.attack != "none"
            and bool(byzantine_mask(robust, num_clients).any()))


def attack_wires(robust, wires, mask, key):
    """Apply the configured byzantine transform to the masked rows of
    a packed (N, rows, cols) wire stack.

    ``mask`` is the (N,) per-row malicious indicator (bool, traced or
    constant); ``key`` seeds the ``random_wire`` noise (ignored by the
    deterministic attacks).  Output has the input's shape and dtype —
    wire geometry and headers are untouched, only payload values
    change:

    * ``sign_flip``    — ``-x`` (gradient ascent on delivery);
    * ``scale``        — ``attack_scale * x`` (model-poisoning boost);
    * ``random_wire``  — gaussian noise matched to each wire's own
      per-client standard deviation (a garbage but plausibly-scaled
      payload).
    """
    if robust.attack not in ATTACKS:
        raise ValueError(
            f"unknown attack {robust.attack!r} (want one of {ATTACKS})")
    if robust.attack == "none":
        return wires
    x = wires.astype(jnp.float32)
    m = jnp.asarray(mask).reshape((-1,) + (1,) * (x.ndim - 1))
    if robust.attack == "sign_flip":
        evil = -x
    elif robust.attack == "scale":
        evil = jnp.float32(robust.attack_scale) * x
    else:  # random_wire
        std = jnp.std(x, axis=tuple(range(1, x.ndim)), keepdims=True)
        noise = jax.random.normal(jax.random.fold_in(key, ATTACK_SALT),
                                  x.shape, jnp.float32)
        evil = noise * jnp.maximum(std, jnp.float32(1e-8))
    return jnp.where(m, evil, x).astype(wires.dtype)


def corrupt_labels(robust, labels, mask, num_classes: int) -> np.ndarray:
    """Label-noise clients: resample each masked client's labels
    uniformly with probability ``label_noise_rate``.

    ``labels`` is a host-side int array with leading client axis C
    (any trailing shape); returns a fresh array, deterministic per
    ``robust.seed``.  Runs at data-build time, so the jitted round
    never carries corruption ops.
    """
    out = np.array(labels)
    if robust.label_noise_fraction <= 0.0 or robust.label_noise_rate <= 0.0:
        return out
    rng = np.random.default_rng([robust.seed, 2])
    flip = rng.random(out.shape) < robust.label_noise_rate
    rand = rng.integers(0, num_classes, out.shape)
    flip &= np.asarray(mask, dtype=bool).reshape(
        (-1,) + (1,) * (out.ndim - 1))
    out[flip] = rand[flip]
    return out
