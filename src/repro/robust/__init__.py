"""Adversarial fleet: byzantine fault injection + robust aggregation.

Two orthogonal layers over the packed wire substrate (`repro.comm.flat`):

* `attacks` — the fault-injection model.  A deterministic subset of
  clients (``RobustConfig.attack_fraction`` of the fleet, chosen per
  ``RobustConfig.seed``) is *byzantine*: their packed uplink wire
  buffers are transformed after encoding (sign-flip, scaled-gradient,
  random-wire — the ``ATTACKS`` registry in `repro.configs.base`).
  A second deterministic subset trains on noisy labels, and the
  virtual-clock scheduler (`repro.sched`) injects dropout/rejoin
  events that delay deliveries by ``rejoin_delay_s`` virtual seconds.
* `aggregators` — pluggable robust server-side combination of the
  (K, rows, cols) arrival stack (the ``AGGREGATORS`` registry):
  ``trimmed_mean`` drops per-coordinate extremes sort-free,
  ``coordinate_median`` is its maximal trim, ``norm_clip`` rescales
  each arrival to a bounded L2 norm.  The Pallas fast path is
  `repro.kernels.robust_agg`; the jnp oracle is
  `repro.kernels.ref.robust_agg_ref`.

Degeneracy contract (docs/robustness.md, pinned by
tests/test_robust.py): ``aggregator="mean"``, ``trimmed_mean`` at
trim count 0 and ``norm_clip`` at clip 0 all *resolve* to the
untouched weighted-mean path — same traced graph, bitwise-identical
round outputs — and with ``attack="none"`` no attack op enters the
graph at all.
"""
from repro.configs.base import AGGREGATORS, ATTACKS, RobustConfig  # noqa: F401
from repro.robust.aggregators import (aggregate_stack, clip_scales,  # noqa: F401
                                      resolve, trim_count)
from repro.robust.attacks import (attack_wires, byzantine_mask,  # noqa: F401
                                  corrupt_labels, label_noise_mask,
                                  wire_attack_active)
