"""Pluggable robust server-side aggregation (repro.robust).

Every federated combine in this repo is a weighted reduction of a
packed ``(K, rows, cols)`` stack of client contributions — the engine
rounds reduce the cohort axis, the virtual-time scheduler reduces its
arrival buffer with staleness weights.  This module swaps that
reduction for a byzantine-robust one without touching the layout:

* ``trimmed_mean`` — per coordinate, drop the ``trim_count`` largest
  and smallest surviving values, then take the weighted mean of the
  survivors (normalizing by the *surviving* weight, which varies per
  coordinate).
* ``coordinate_median`` — the maximal trim ``(K-1)//2`` per side: one
  survivor for odd K (the median), the two middle values for even K
  (their weighted mean).  A special case of the same kernel.
* ``norm_clip`` — rescale each arrival to L2 norm at most
  ``clip_norm`` (``x_k * min(1, clip/||x_k||)``), then the usual
  weighted mean.  Values shrink, weights do not.

Degenerate parameterizations (`resolve` returns ``"mean"``) mean the
caller keeps its existing weighted-mean code path — the *same traced
graph* as today, hence bitwise-identical outputs (the contract of
docs/robustness.md, pinned by tests/test_robust.py).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import AGGREGATORS


def trim_count(robust, K: int) -> int:
    """Static per-side trim count for a K-arrival stack.

    ``trimmed_mean`` trims ``floor(trim_fraction * K)`` per side,
    capped so at least one coordinate survives; ``coordinate_median``
    is the maximal trim.  0 for everything else.
    """
    if robust.aggregator == "trimmed_mean":
        return min(int(robust.trim_fraction * K), max(0, (K - 1) // 2))
    if robust.aggregator == "coordinate_median":
        return (K - 1) // 2
    return 0


def resolve(robust, K: int) -> str:
    """Effective aggregator for a K-arrival stack.

    Degenerate parameterizations resolve to ``"mean"`` — the caller
    then keeps today's weighted-mean path untouched (bitwise):
    ``trimmed_mean`` whose trim count rounds to 0, ``coordinate_median``
    of a single arrival, ``norm_clip`` with the clip disabled.
    """
    agg = robust.aggregator
    if agg not in AGGREGATORS:
        raise ValueError(
            f"unknown aggregator {agg!r} (want one of {AGGREGATORS})")
    if agg == "trimmed_mean" and trim_count(robust, K) == 0:
        return "mean"
    if agg == "coordinate_median" and K <= 1:
        return "mean"
    if agg == "norm_clip" and robust.clip_norm <= 0.0:
        return "mean"
    return agg


def clip_scales(wires, clip_norm) -> jnp.ndarray:
    """(K,) fp32 rescale factors ``min(1, clip_norm / ||x_k||_2)``.

    Idempotent by construction: an arrival already inside the norm
    ball (``||x_k|| <= clip_norm``) gets the factor exactly 1.0 — the
    ``where`` form, not a ``min`` of rounded quotients — so clipping
    an in-ball stack is a bitwise no-op (pinned by
    tests/test_property.py).
    """
    x = wires.astype(jnp.float32)
    nrm = jnp.sqrt(jnp.sum(x * x, axis=(1, 2)))
    return jnp.where(nrm <= clip_norm, jnp.float32(1.0),
                     clip_norm / jnp.maximum(nrm, jnp.float32(1e-30)))


def aggregate_stack(robust, wires, weights, *, normalize: bool = True,
                    use_pallas: bool = False, interpret: bool = True):
    """Robust combine of a (K, rows, cols) stack -> (rows, cols) fp32.

    ``weights`` are the caller's per-arrival weights (ones for an
    engine cohort, staleness weights in the scheduler).  With
    ``normalize`` the result is the weighted mean of the per-coordinate
    survivors; without it (the scheduler's async apply) the surviving
    ``sum_k w_k x_k`` is returned raw — trimmed-away arrivals simply
    never contribute.  ``use_pallas`` routes through the fused
    sort-free kernel (`repro.kernels.robust_agg`); the jnp path is the
    conformance oracle `repro.kernels.ref.robust_agg_ref` itself.
    """
    from repro.kernels import ref as kref
    K = wires.shape[0]
    eff = resolve(robust, K)
    w = jnp.asarray(weights, jnp.float32)
    if eff == "mean":
        # degenerate call — mirror the callers' weighted-mean semantics
        num = jnp.sum(wires.astype(jnp.float32) * w[:, None, None],
                      axis=0)
        return num / jnp.sum(w) if normalize else num
    if eff == "norm_clip":
        s = clip_scales(wires, robust.clip_norm)
        t = 0
    else:
        s = jnp.ones((K,), jnp.float32)
        t = trim_count(robust, K)
    if use_pallas:
        from repro.kernels.robust_agg import robust_agg_flat
        return robust_agg_flat(wires, w, s, trim=t, normalize=normalize,
                               interpret=interpret)
    return kref.robust_agg_ref(wires, w, s, trim=t, normalize=normalize)
