"""Fused sort-free robust-aggregation Pallas TPU kernel.

The robust combine of `repro.robust.aggregators` over a (K, R, C)
arrival stack:

    x'_k  = scales[k] * wires[k]                      (norm-clip rescale)
    mask  = survivors after dropping the `trim` per-coordinate
            extremes per side
    num   = sum_k  mask_k * weights[k] * x'_k
    out   = num / sum_k mask_k * weights[k]           (normalize=True)
          = num                                       (normalize=False)

Left to XLA, per-coordinate trimming is a (K, R, C) sort — O(K log K)
passes and several HBM-sized temporaries.  The kernel is *sort-free*:
each (br, bc) tile holds the full K axis in VMEM and extracts one
extreme per pass with an argmax/iota mask (``trim`` is small — the
trim count is capped at ``(K-1)//2`` — so 2*trim statically-unrolled
passes beat a sort for every real buffer size), reading every wire
from HBM exactly once.  ``coordinate_median`` is the same kernel at
the maximal trim: the surviving one (odd K) or two (even K) middle
values ARE the median.

Ties break to the lowest arrival index (argmax semantics), matching
the oracle `repro.kernels.ref.robust_agg_ref` exactly — kernel vs
ref is pinned per-dtype by tests/test_robust.py.  Layout matches
`repro.comm.flat`: fp32/bf16/fp8 (K, rows, cols) stacks, loads
upcast to fp32 in VMEM, fp32 out.  ``interpret=True`` runs the body
on CPU (this container); pass False on a real TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning


def _survivor_mask(x, trim: int):
    """(K, br, bc) bool survivor mask after removing `trim` extremes
    per side per coordinate — one occurrence per pass, first arrival
    index wins ties."""
    mask = jnp.ones(x.shape, jnp.bool_)
    if trim == 0:
        return mask
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    big = jnp.float32(jnp.finfo(jnp.float32).max)
    for sign in (1.0, -1.0):
        for _ in range(trim):
            cand = jnp.where(mask, jnp.float32(sign) * x, -big)
            hit = jnp.argmax(cand, axis=0)
            mask = mask & (iota != hit[None])
    return mask


def _robust_agg_kernel(x_ref, w_ref, s_ref, out_ref, *, trim,
                       normalize):
    """One (br, bc) output tile; the whole K axis lives in the block."""
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].reshape(-1, 1, 1)
    s = s_ref[...].reshape(-1, 1, 1)
    xs = s * x
    wm = jnp.where(_survivor_mask(xs, trim), w, jnp.float32(0.0))
    num = jnp.sum(xs * wm, axis=0)
    if normalize:
        num = num / jnp.sum(wm, axis=0)
    out_ref[...] = num


@functools.partial(jax.jit, static_argnames=("trim", "normalize",
                                             "interpret", "blocks"))
def robust_agg_flat(wires, weights, scales, *, trim: int,
                    normalize: bool = True, interpret: bool = True,
                    blocks=None):
    """Fused sort-free trimmed-mean/clip combine of K arrival wires.

    wires: (K, R, C) packed contributions (fp32, bf16 or fp8 — loads
    upcast in-kernel); weights: (K,) arrival weights; scales: (K,)
    per-arrival value rescales (the norm-clip factors; ones when
    unused).  ``trim`` extremes are dropped per coordinate per side
    (static; requires ``2*trim < K``).  Returns the (R, C) fp32
    robust aggregate.  blocks: optional static (br, bc) override of
    the tuned tile.
    """
    K, R, C = wires.shape
    if not 2 * trim < K:
        raise ValueError(f"trim={trim} must satisfy 2*trim < K={K}")
    if blocks is not None:
        br, bc = blocks
        br, bc = min(br, R), min(bc, C)
    else:
        br, bc = tuning.blocks_2d("robust_agg", R, C,
                                  dtype=wires.dtype)
    # 2D grid — no tile revisits: trimming needs all K wires at once,
    # so K is a block axis, not a grid axis
    grid = (pl.cdiv(R, br), pl.cdiv(C, bc))
    w2 = jnp.asarray(weights, jnp.float32).reshape(K, 1)
    s2 = jnp.asarray(scales, jnp.float32).reshape(K, 1)
    with jax.named_scope("pallas:robust_agg_flat"):
        return pl.pallas_call(
            functools.partial(_robust_agg_kernel, trim=trim,
                              normalize=normalize),
            grid=grid,
            in_specs=[pl.BlockSpec((K, br, bc),
                                   lambda i, j: (0, i, j)),
                      pl.BlockSpec((K, 1), lambda i, j: (0, 0)),
                      pl.BlockSpec((K, 1), lambda i, j: (0, 0))],
            out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
            interpret=interpret,
        )(wires, w2, s2)
