"""Fused Pallas TPU kernels for the engine's HBM-bound hot paths.

Every kernel consumes the packed ``(rows, cols)`` wire layout of
`repro.comm.flat` directly — the flat-resident engine hands them state
that is *already* in their layout, so the kernel path performs zero
pytree<->flat conversion (gated by ``make bench-engine-smoke``):

* `sophia_update.sophia_update_flat` — the fused Sophia local
  iteration (m-EMA, gated h-EMA, decay, clip, step) over theta/m/h/g.
* `quantize.quant_roundtrip_flat` / `uplink_roundtrip_flat` /
  `broadcast_roundtrip_flat` / `sign_roundtrip_flat` /
  `topk_threshold_flat` — the wire round-trips of the comm streams
  (delta-code + EF + stochastic quant + residual in one VMEM pass).
* `stale_accum.stale_accum_flat` — the scheduler's staleness-weighted
  buffered aggregation.
* `robust_agg.robust_agg_flat` — the sort-free trimmed-mean/clip
  robust combine of `repro.robust` over the (K, rows, cols) stack.
* `ref` — pure-jnp oracles with identical per-coordinate semantics
  (the equivalence targets in tests/test_kernels.py).

Dtype contract: resident state may be stored bf16
(`CommConfig.state_dtype="bfloat16"`).  Kernels and refs upcast loads
to fp32, compute in fp32, and store each output in that output's
declared dtype; noise/scales/weights are always fp32.  With fp32
inputs all casts are no-ops — the default path is bit-identical to
the pre-dtype kernels.

Donation-safety: the kernels allocate fresh outputs; in-place update
of the resident buffers happens one level up, where the jitted round
donates its state (`FedEngine.round_fn`) and XLA aliases these
outputs onto the donated inputs.  Kernel callers never need to think
about aliasing; round callers do (docs/architecture.md "Memory
layout: the life of a round").

Client batching: every wire/optimizer kernel also has a ``*_batched``
entry point that takes the packed (C, rows, cols) client stack and
runs it as ONE launch with a leading client grid dimension, instead
of C vmapped (rows, cols) launches.  The batched launches reuse the
same kernel bodies over 3D blocks, so batched == per-client bitwise
(pinned by tests/test_kernel_conformance.py).  Block shapes — the
client block included — come from the committed ``tuning.json`` via
`repro.kernels.tuning` (autotuned by tools/autotune_kernels.py, safe
defaults when absent).

This layer is OPTIONAL: add <name>.py + a ref oracle ONLY for compute
hot-spots that are demonstrably HBM- or compute-bound; everything
else belongs in plain jnp.
"""
import jax

# Pallas kernels execute in interpret mode everywhere but real TPUs
# (this container is CPU-only); shared by ops.py and repro.comm.
INTERPRET = jax.default_backend() != "tpu"

# The kernel registry: one name per fused kernel family, used as the
# key space of kernels/tuning.json (validated by tools/check_docs.py
# and `make autotune-check`) and swept by tools/autotune_kernels.py.
KERNELS = (
    "quant_roundtrip",
    "broadcast_roundtrip",
    "uplink_roundtrip",
    "sign_roundtrip",
    "topk_threshold",
    "sophia_update",
    "stale_accum",
    "robust_agg",
)
