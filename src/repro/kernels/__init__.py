"""Fused Pallas TPU kernels for the engine's HBM-bound hot paths.

Every kernel consumes the packed ``(rows, cols)`` wire layout of
`repro.comm.flat` directly — the flat-resident engine hands them state
that is *already* in their layout, so the kernel path performs zero
pytree<->flat conversion (gated by ``make bench-engine-smoke``):

* `sophia_update.sophia_update_flat` — the fused Sophia local
  iteration (m-EMA, gated h-EMA, decay, clip, step) over theta/m/h/g.
* `quantize.quant_roundtrip_flat` / `uplink_roundtrip_flat` /
  `broadcast_roundtrip_flat` / `sign_roundtrip_flat` /
  `topk_threshold_flat` — the wire round-trips of the comm streams
  (delta-code + EF + stochastic quant + residual in one VMEM pass).
* `stale_accum.stale_accum_flat` — the scheduler's staleness-weighted
  buffered aggregation.
* `ref` — pure-jnp oracles with identical per-coordinate semantics
  (the equivalence targets in tests/test_kernels.py).

Dtype contract: resident state may be stored bf16
(`CommConfig.state_dtype="bfloat16"`).  Kernels and refs upcast loads
to fp32, compute in fp32, and store each output in that output's
declared dtype; noise/scales/weights are always fp32.  With fp32
inputs all casts are no-ops — the default path is bit-identical to
the pre-dtype kernels.

Donation-safety: the kernels allocate fresh outputs; in-place update
of the resident buffers happens one level up, where the jitted round
donates its state (`FedEngine.round_fn`) and XLA aliases these
outputs onto the donated inputs.  Kernel callers never need to think
about aliasing; round callers do (docs/architecture.md "Memory
layout: the life of a round").

This layer is OPTIONAL: add <name>.py + a ref oracle ONLY for compute
hot-spots that are demonstrably HBM- or compute-bound; everything
else belongs in plain jnp.
"""
import jax

# Pallas kernels execute in interpret mode everywhere but real TPUs
# (this container is CPU-only); shared by ops.py and repro.comm.
INTERPRET = jax.default_backend() != "tpu"
