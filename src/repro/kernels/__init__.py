# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
import jax

# Pallas kernels execute in interpret mode everywhere but real TPUs
# (this container is CPU-only); shared by ops.py and repro.comm.
INTERPRET = jax.default_backend() != "tpu"
