"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def sophia_update_ref(theta, m, h, g, h_hat, do_h, *, lr, beta1, beta2,
                      rho, eps, weight_decay):
    """Reference semantics of the fused Sophia update (flat arrays)."""
    do_h = jnp.asarray(do_h, jnp.float32)
    m = beta1 * m + (1.0 - beta1) * g
    h_new = beta2 * h + (1.0 - beta2) * h_hat
    h = do_h * h_new + (1.0 - do_h) * h
    theta = theta - lr * weight_decay * theta
    step = jnp.clip(m / jnp.maximum(h, eps), -rho, rho)
    return theta - lr * step, m, h


def quant_roundtrip_ref(x, noise, scale, *, qmax):
    """Reference for kernels.quantize.quant_roundtrip_flat: per-row-scale
    stochastic quantize then dequantize."""
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.floor(x / safe + noise), -qmax, qmax)
    return q * scale


def uplink_roundtrip_ref(theta, start, ef, noise, scale, *, qmax):
    """Reference for kernels.quantize.uplink_roundtrip_flat: EF-corrected
    uplink delta, quant round-trip, new residual."""
    d = (theta - start) + ef
    xhat = quant_roundtrip_ref(d, noise, scale, qmax=qmax)
    return xhat, d - xhat


def sign_roundtrip_ref(x, scale):
    """Reference for kernels.quantize.sign_roundtrip_flat."""
    return jnp.asarray(scale, jnp.float32) * jnp.sign(x)


def topk_threshold_ref(x, thr):
    """Reference for kernels.quantize.topk_threshold_flat."""
    return jnp.where(jnp.abs(x) >= thr, x, 0.0)


def stale_accum_ref(wires, weights, inv_norm):
    """Reference for kernels.stale_accum.stale_accum_flat: staleness-
    weighted accumulate of K arrival wires."""
    w = jnp.asarray(weights, jnp.float32)[:, None, None]
    return jnp.asarray(inv_norm, jnp.float32) * jnp.sum(
        wires.astype(jnp.float32) * w, axis=0)
