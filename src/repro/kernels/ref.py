"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def sophia_update_ref(theta, m, h, g, h_hat, do_h, *, lr, beta1, beta2,
                      rho, eps, weight_decay):
    """Reference semantics of the fused Sophia update (flat arrays)."""
    do_h = jnp.asarray(do_h, jnp.float32)
    m = beta1 * m + (1.0 - beta1) * g
    h_new = beta2 * h + (1.0 - beta2) * h_hat
    h = do_h * h_new + (1.0 - do_h) * h
    theta = theta - lr * weight_decay * theta
    step = jnp.clip(m / jnp.maximum(h, eps), -rho, rho)
    return theta - lr * step, m, h
