"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests).

Dtype contract (mirrors `repro.kernels.quantize`): every ref upcasts
its state operands to fp32, computes in fp32, and casts each output
back to the corresponding input's storage dtype — so a bf16 resident
buffer (`CommConfig.state_dtype="bfloat16"`) produces the same
rounding as the kernels' in-VMEM load/store path, and with fp32
inputs the casts are no-ops and the refs are unchanged.
"""
from __future__ import annotations

import jax.numpy as jnp


def _f32(x):
    return x.astype(jnp.float32)


def sophia_update_ref(theta, m, h, g, h_hat, do_h, *, lr, beta1, beta2,
                      rho, eps, weight_decay):
    """Reference semantics of the fused Sophia update (flat arrays).
    Returns (theta, m, h) in their input storage dtypes."""
    out_dt = (theta.dtype, m.dtype, h.dtype)
    do_h = jnp.asarray(do_h, jnp.float32)
    theta, m, h, g, h_hat = map(_f32, (theta, m, h, g, h_hat))
    m = beta1 * m + (1.0 - beta1) * g
    h_new = beta2 * h + (1.0 - beta2) * h_hat
    h = do_h * h_new + (1.0 - do_h) * h
    theta = theta - lr * weight_decay * theta
    step = jnp.clip(m / jnp.maximum(h, eps), -rho, rho)
    return ((theta - lr * step).astype(out_dt[0]), m.astype(out_dt[1]),
            h.astype(out_dt[2]))


def quant_roundtrip_ref(x, noise, scale, *, qmax):
    """Reference for kernels.quantize.quant_roundtrip_flat: per-row-scale
    stochastic quantize then dequantize (output in x's dtype)."""
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.floor(_f32(x) / safe + noise), -qmax, qmax)
    return (q * scale).astype(x.dtype)


def uplink_roundtrip_ref(theta, start, ef, noise, scale, *, qmax):
    """Reference for kernels.quantize.uplink_roundtrip_flat: EF-corrected
    uplink delta, quant round-trip, new residual."""
    d = (_f32(theta) - _f32(start)) + _f32(ef)
    xhat = quant_roundtrip_ref(d, noise, scale, qmax=qmax)
    # both outputs in theta's dtype, matching the kernel's out_shape
    return xhat.astype(theta.dtype), (d - xhat).astype(theta.dtype)


def broadcast_roundtrip_ref(theta, ref, ef, noise, scale, *, qmax):
    """Reference for kernels.quantize.broadcast_roundtrip_flat: delta-
    coded broadcast round-trip, replica apply, new residual."""
    r = _f32(ref)
    d = (_f32(theta) - r) + _f32(ef)
    xhat = quant_roundtrip_ref(d, noise, scale, qmax=qmax)
    return (r + xhat).astype(theta.dtype), (d - xhat).astype(theta.dtype)


def _per_client(s, x):
    """Align a scalar (2D launch) or (N,) per-client (batched launch)
    scale against x for broadcasting."""
    s = jnp.asarray(s, jnp.float32)
    return s.reshape(s.shape + (1,) * (x.ndim - s.ndim))


def sign_roundtrip_ref(x, scale):
    """Reference for kernels.quantize.sign_roundtrip_flat /
    sign_roundtrip_batched (scale scalar or (N,))."""
    return (_per_client(scale, x) * jnp.sign(_f32(x))).astype(x.dtype)


def topk_threshold_ref(x, thr):
    """Reference for kernels.quantize.topk_threshold_flat /
    topk_threshold_batched (thr scalar or (N,))."""
    xf = _f32(x)
    return jnp.where(jnp.abs(xf) >= _per_client(thr, x), xf,
                     0.0).astype(x.dtype)


def stale_accum_ref(wires, weights, inv_norm):
    """Reference for kernels.stale_accum.stale_accum_flat: staleness-
    weighted accumulate of K arrival wires (always fp32 out)."""
    w = jnp.asarray(weights, jnp.float32)[:, None, None]
    return jnp.asarray(inv_norm, jnp.float32) * jnp.sum(
        _f32(wires) * w, axis=0)


def robust_agg_ref(wires, weights, scales, *, trim, normalize=True):
    """Reference for kernels.robust_agg.robust_agg_flat: sort-free
    trimmed/clipped weighted combine of K arrival wires (fp32 out).

    Per coordinate, ``trim`` extremes per side are removed one
    occurrence at a time (lowest arrival index wins ties — argmax
    semantics, identical to the kernel); survivors are combined as
    ``sum_k w_k * scales_k * x_k`` over the surviving k, divided by
    the surviving weight when ``normalize``.
    """
    import jax
    x = _f32(wires)
    K = x.shape[0]
    xs = jnp.asarray(scales, jnp.float32)[:, None, None] * x
    mask = jnp.ones(xs.shape, jnp.bool_)
    if trim:
        iota = jax.lax.broadcasted_iota(jnp.int32, xs.shape, 0)
        big = jnp.float32(jnp.finfo(jnp.float32).max)
        for sign in (1.0, -1.0):
            for _ in range(trim):
                cand = jnp.where(mask, jnp.float32(sign) * xs, -big)
                hit = jnp.argmax(cand, axis=0)
                mask = mask & (iota != hit[None])
    wm = jnp.where(mask, jnp.asarray(weights, jnp.float32)[:, None, None],
                   jnp.float32(0.0))
    num = jnp.sum(xs * wm, axis=0)
    if normalize:
        num = num / jnp.sum(wm, axis=0)
    return num
