"""Pytree-level wrapper around the fused Sophia Pallas kernel.

``sophia_fused_step`` packs every leaf of the param pytree into one
flat (R, C) buffer, runs the fused kernel once, and unpacks.  It is
the *pytree-boundary* form kept for `repro.core.sophia.sophia_step`
(the reference twin) and its tests; the round engine itself is
flat-resident (`repro.core.fed`) and calls
`repro.kernels.sophia_update.sophia_update_flat` directly on wire-
layout state — zero pack/unpack per local iteration.

The dead apply-only wrapper (``sophia_apply_fused``) that allocated a
full zeros gradient buffer to run the complete kernel was removed;
use `repro.core.sophia.apply_update` for apply-only semantics.
"""
from __future__ import annotations

from repro.comm.flat import flat_spec, pack, unpack
from repro.kernels import INTERPRET as _INTERPRET
from repro.kernels.sophia_update import BLOCK_C, sophia_update_flat


def _pack(trees):
    """Pack each tree into the shared wire layout -> (flat_2d list, spec)."""
    spec = flat_spec(trees[0], cols=BLOCK_C)
    return [pack(t, spec) for t in trees], spec


def _unpack(flat2d, spec):
    return unpack(flat2d, spec)


def sophia_fused_step(params, m, h, grads, h_hat, do_h, *, lr, beta1, beta2,
                      rho, eps, weight_decay, interpret=None):
    """Fused m-EMA + h-EMA-select + decay + clip + update over a pytree.

    Returns (new_params, new_m, new_h).
    """
    if interpret is None:
        interpret = _INTERPRET
    (t2, m2, h2, g2, hh2), meta = _pack([params, m, h, grads, h_hat])
    t2, m2, h2 = sophia_update_flat(
        t2, m2, h2, g2, hh2, do_h, lr, beta1=beta1, beta2=beta2,
        rho=rho, eps=eps, weight_decay=weight_decay, interpret=interpret)
    return _unpack(t2, meta), _unpack(m2, meta), _unpack(h2, meta)
