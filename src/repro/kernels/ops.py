"""jit'd pytree-level wrappers around the Pallas kernels.

``sophia_apply_fused`` packs every floating leaf of the param pytree into
one flat (R, C) buffer, runs the fused kernel once, and unpacks — one
kernel launch per local iteration regardless of model structure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.comm.flat import flat_spec, pack, unpack
from repro.kernels import INTERPRET as _INTERPRET
from repro.kernels.sophia_update import BLOCK_C, sophia_update_flat


def _pack(trees):
    """Pack each tree into the shared wire layout -> (flat_2d list, spec)."""
    spec = flat_spec(trees[0], cols=BLOCK_C)
    return [pack(t, spec) for t in trees], spec


def _unpack(flat2d, spec):
    return unpack(flat2d, spec)


def sophia_fused_step(params, m, h, grads, h_hat, do_h, *, lr, beta1, beta2,
                      rho, eps, weight_decay, interpret=None):
    """Fused m-EMA + h-EMA-select + decay + clip + update over a pytree.

    Returns (new_params, new_m, new_h).
    """
    if interpret is None:
        interpret = _INTERPRET
    (t2, m2, h2, g2, hh2), meta = _pack([params, m, h, grads, h_hat])
    t2, m2, h2 = sophia_update_flat(
        t2, m2, h2, g2, hh2, do_h, lr, beta1=beta1, beta2=beta2,
        rho=rho, eps=eps, weight_decay=weight_decay, interpret=interpret)
    return _unpack(t2, meta), _unpack(m2, meta), _unpack(h2, meta)


def sophia_apply_fused(params, m, h, *, lr, rho, eps, weight_decay,
                       interpret=None):
    """Apply-only variant used by core.sophia when the EMAs are already
    updated (matches sophia.apply_update semantics)."""
    if interpret is None:
        interpret = _INTERPRET
    (t2, m2, h2), meta = _pack([params, m, h])
    zeros = jnp.zeros_like(t2)
    # beta1=1, beta2=1 make the EMAs no-ops; do_h=0 keeps h unchanged.
    t2, _, _ = sophia_update_flat(
        t2, m2, h2, zeros, zeros, 0.0, lr, beta1=1.0, beta2=1.0,
        rho=rho, eps=eps, weight_decay=weight_decay, interpret=interpret)
    return _unpack(t2, meta)
