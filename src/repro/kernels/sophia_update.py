"""Fused Sophia parameter update as a Pallas TPU kernel.

The Sophia local iteration is an elementwise state machine over theta/m/h/g
(Alg. 1 lines 8, 11, 15-16). Left to XLA it becomes ~8 HBM-bound
elementwise ops (m-EMA, h-EMA select, max, div, clip, decay, axpy); fusing
them into one VMEM pass reads each of the 4 input streams once and writes
3 output streams once — the HBM-roofline optimum for this op.

TPU mapping: parameters are flattened and tiled into (8, 1024)-multiples
(fp32 VREG tiling is (8,128); 1024 lanes amortises grid overhead).
Each grid step owns one (BLOCK_R, BLOCK_C) tile in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning

BLOCK_R = 256
BLOCK_C = 1024


def _sophia_kernel(theta_ref, m_ref, h_ref, g_ref, hhat_ref, flags_ref,
                   theta_out, m_out, h_out, *, beta1, beta2, rho, eps,
                   weight_decay):
    """One VMEM tile of the fused update.

    flags_ref: (1, 2) scalars — [do_h_update (0/1), lr]. Runtime inputs
    (lr is schedule-driven and traced).  Loads upcast to fp32, stores
    downcast to each output's dtype (bf16 resident state computes in
    fp32; no-op casts for fp32 state).
    """
    do_h = flags_ref[0, 0]
    lr = flags_ref[0, 1]
    g = g_ref[...].astype(jnp.float32)
    h0 = h_ref[...].astype(jnp.float32)
    m = beta1 * m_ref[...].astype(jnp.float32) + (1.0 - beta1) * g  # Eq. 9
    h_new = beta2 * h0 + (1.0 - beta2) * hhat_ref[...].astype(
        jnp.float32)                                               # Eq. 10
    h = do_h * h_new + (1.0 - do_h) * h0
    theta = theta_ref[...].astype(jnp.float32)
    theta = theta - lr * weight_decay * theta                      # line 15
    step = m / jnp.maximum(h, eps)
    step = jnp.clip(step, -rho, rho)                               # Eq. 11
    theta_out[...] = (theta - lr * step).astype(theta_out.dtype)   # line 16
    m_out[...] = m.astype(m_out.dtype)
    h_out[...] = h.astype(h_out.dtype)


@functools.partial(jax.jit, static_argnames=("beta1", "beta2", "rho",
                                             "eps", "weight_decay",
                                             "interpret"))
def sophia_update_flat(theta, m, h, g, h_hat, do_h, lr, *, beta1, beta2,
                       rho, eps, weight_decay, interpret: bool = True):
    """Fused update over a flat (R, C) view. Returns (theta, m, h),
    each in its input's storage dtype (fp32, bf16 or fp8 resident
    state — m and h may each carry their own dtype via
    `CommConfig.moment_dtype` / `hessian_dtype`; compute is fp32
    in-kernel either way).

    interpret=True executes the kernel body in Python on CPU (this
    container); on a real TPU pass interpret=False.
    """
    R, C = theta.shape
    br, bc = tuning.blocks_2d("sophia_update", R, C, dtype=theta.dtype)
    grid = (pl.cdiv(R, br), pl.cdiv(C, bc))
    flags = jnp.stack([jnp.asarray(do_h, jnp.float32).reshape(()),
                       jnp.asarray(lr, jnp.float32).reshape(())]
                      ).reshape(1, 2)

    tile = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    smem = pl.BlockSpec((1, 2), lambda i, j: (0, 0))

    kernel = functools.partial(
        _sophia_kernel, beta1=beta1, beta2=beta2, rho=rho, eps=eps,
        weight_decay=weight_decay)
    out_shape = [jax.ShapeDtypeStruct((R, C), x.dtype)
                 for x in (theta, m, h)]
    # named scope: the kernel launch shows up as an annotated span in
    # jax.profiler traces (--profile-dir); metadata only, the lowered
    # computation is unchanged
    with jax.named_scope("pallas:sophia_update_flat"):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[tile, tile, tile, tile, tile, smem],
            out_specs=[tile, tile, tile],
            out_shape=out_shape,
            interpret=interpret,
        )(theta, m, h, g, h_hat, flags)


@functools.partial(jax.jit, static_argnames=("beta1", "beta2", "rho",
                                             "eps", "weight_decay",
                                             "interpret", "blocks"))
def sophia_update_batched(theta, m, h, g, h_hat, do_h, lr, *, beta1,
                          beta2, rho, eps, weight_decay,
                          interpret: bool = True, blocks=None):
    """`sophia_update_flat` over packed (N, R, C) client stacks in ONE
    launch with a leading client grid dimension.  Reuses the same
    elementwise kernel body over 3D blocks, so results are bitwise
    equal to N per-client launches (tests/test_kernel_conformance.py).
    do_h / lr stay shared scalars — every client steps the same local
    iteration of the same round.  blocks: optional static (bn, br, bc)
    override of the tuned geometry."""
    N, R, C = theta.shape
    bn, br, bc = tuning.blocks_for("sophia_update", N, R, C,
                                   override=blocks, dtype=theta.dtype)
    grid = (pl.cdiv(N, bn), pl.cdiv(R, br), pl.cdiv(C, bc))
    flags = jnp.stack([jnp.asarray(do_h, jnp.float32).reshape(()),
                       jnp.asarray(lr, jnp.float32).reshape(())]
                      ).reshape(1, 2)

    tile3 = pl.BlockSpec((bn, br, bc), lambda n, i, j: (n, i, j))
    smem = pl.BlockSpec((1, 2), lambda n, i, j: (0, 0))

    kernel = functools.partial(
        _sophia_kernel, beta1=beta1, beta2=beta2, rho=rho, eps=eps,
        weight_decay=weight_decay)
    out_shape = [jax.ShapeDtypeStruct((N, R, C), x.dtype)
                 for x in (theta, m, h)]
    with jax.named_scope("pallas:sophia_update_batched"):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[tile3, tile3, tile3, tile3, tile3, smem],
            out_specs=[tile3, tile3, tile3],
            out_shape=out_shape,
            interpret=interpret,
        )(theta, m, h, g, h_hat, flags)
