"""Fused staleness-weighted delta-accumulate Pallas TPU kernel.

The virtual-time scheduler (`repro.sched`) aggregates a buffer of K
arrival wires with per-arrival staleness weights:

    agg = inv_norm * sum_k weights[k] * wires[k]

(`inv_norm = 1/sum(weights)` for the semisync weighted mean, 1.0 for
the async unnormalized apply).  Left to XLA this is a broadcast
multiply materialising a (K, R, C) temporary plus a reduction — two
HBM passes over the K wires.  The kernel walks the K axis innermost
over each (R, C) tile, accumulating in VMEM: every wire is read once
and the aggregate written once, the same HBM-roofline argument as the
quantize round-trips in `repro.kernels.quantize`.

Layout matches `repro.comm.flat`: fp32 (rows, cols) tiles.  The
reference oracle is `repro.kernels.ref.stale_accum_ref`;
``interpret=True`` runs the kernel body on CPU (this container), pass
False on a real TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning

BLOCK_R = 256
BLOCK_C = 1024


def _stale_accum_kernel(x_ref, w_ref, s_ref, out_ref, *, num_steps,
                        block_k):
    """One (br, bc) output tile, revisited across the K-axis grid
    steps.  Each step folds ``block_k`` wires into the tile with the
    same left-to-right fp32 adds as block_k=1 grid steps would (the
    in-kernel loop unrolls statically), so the blocked launch is
    bitwise equal to the unblocked one.  Loads upcast to fp32 in VMEM
    (bf16 wires stream at half the HBM bandwidth; the accumulator is
    always fp32)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = out_ref[...]
    for kk in range(block_k):
        acc = acc + w_ref[kk, 0] * x_ref[kk, ...].astype(jnp.float32)
    out_ref[...] = acc

    @pl.when(k == num_steps - 1)
    def _scale():
        out_ref[...] *= s_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret", "blocks"))
def stale_accum_flat(wires, weights, inv_norm, *, interpret: bool = True,
                     blocks=None):
    """Fused weighted accumulate over K arrival wires.

    wires: (K, R, C) packed deltas (fp32, bf16 or fp8 — loads upcast
    in-kernel, so narrow wires never materialize an fp32 copy in HBM);
    weights: (K,) staleness weights; inv_norm: scalar final scale
    (traced).  Returns the (R, C) fp32 aggregate
    ``inv_norm * sum_k weights[k] * wires[k]``.  blocks: optional
    static (bk, br, bc) override of the tuned geometry.

    The committed tuning only resizes (br, bc): folding several wires
    inside one kernel invocation (bk > 1) keeps the fp32 add order
    but lets the backend contract mul+add into FMAs, which is
    allclose- but not bitwise-equal to per-step accumulation — so
    bk > 1 is opt-in via ``blocks`` and never chosen by the tuned
    path (tests/test_kernel_conformance.py pins both behaviours).
    """
    K, R, C = wires.shape
    if blocks is not None:
        bk, br, bc = tuning.blocks_for("stale_accum", K, R, C,
                                       override=blocks)
    else:
        bk = 1
        br, bc = tuning.blocks_2d("stale_accum", R, C,
                                  dtype=wires.dtype)
    # accumulation revisits the output tile across K-axis steps, so a
    # partial tail block would double-count padding: only block K when
    # it divides exactly
    if K % bk != 0:
        bk = 1
    # K innermost: each output tile is revisited on consecutive grid
    # steps (the TPU-legal accumulation pattern)
    grid = (pl.cdiv(R, br), pl.cdiv(C, bc), K // bk)
    w2 = jnp.asarray(weights, jnp.float32).reshape(K, 1)
    s2 = jnp.asarray(inv_norm, jnp.float32).reshape(1, 1)
    # named scope: annotated span in jax.profiler traces; metadata only
    with jax.named_scope("pallas:stale_accum_flat"):
        return pl.pallas_call(
            functools.partial(_stale_accum_kernel, num_steps=K // bk,
                              block_k=bk),
            grid=grid,
            in_specs=[pl.BlockSpec((bk, br, bc),
                                   lambda i, j, k: (k, i, j)),
                      pl.BlockSpec((bk, 1), lambda i, j, k: (k, 0)),
                      pl.BlockSpec((1, 1), lambda i, j, k: (0, 0))],
            out_specs=pl.BlockSpec((br, bc), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
            interpret=interpret,
        )(wires, w2, s2)
