"""Trace-time block-size resolution for the Pallas kernels.

The kernels tile their (rows, cols) — and, for the client-batched
entry points, (clients, rows, cols) — operands into VMEM blocks.  The
best block shape is hardware- and size-dependent: on a real TPU it is
a VMEM-budget question; in interpret mode (CPU, this container) the
dominant cost is per-grid-step dispatch overhead, so bigger blocks
(fewer grid steps) win outright.

`tools/autotune_kernels.py` sweeps candidate blocks at the committed
benchmark sizes and writes the winners to ``tuning.json`` next to
this module.  Kernels consult it AT TRACE TIME through `blocks_for` /
`blocks_2d`; block shape never changes kernel *values* (every entry
point is elementwise per coordinate — pinned bitwise across
geometries by tests/test_kernel_conformance.py), only launch
geometry, so a stale or missing file is always safe.  One caveat for
WHOLE-PROGRAM bitwise comparisons: in interpret mode a different
grid restructures the surrounding jitted program, which can move
XLA:CPU's per-fusion FMA contraction and shift last-ulp results of
*other* ops in the same jit — tests that pin two differently
structured programs bitwise (tests/test_flat_engine.py) therefore
fix the geometry first.  Fallback behaviour:

* no ``tuning.json`` / unreadable / malformed entry -> the safe
  defaults below (``DEFAULT_BLOCK_R x DEFAULT_BLOCK_C`` tiles, one
  client per grid step — exactly the pre-tuning launch geometry);
* an entry larger than the operand -> clamped to the operand;
* keys are validated against `repro.kernels.KERNELS` by
  ``tools/check_docs.py`` and ``make autotune-check``.

The file format (versioned, committed at the repo root of the
package)::

    {"version": 1,
     "backend": "cpu-interpret",
     "entries": {"<kernel>": {"block_n": 8, "block_r": 256,
                              "block_c": 1024}, ...}}

``block_n`` batches the client axis of the batched launches (and the
K wire axis of ``stale_accum``); ``block_r``/``block_c`` tile the
packed wire buffer.

Entry keys carry optional specificity suffixes::

    <kernel>                       the dtype-agnostic default
    <kernel>@<dtype>               per-dtype geometry (operand dtype
                                   name, e.g. "bfloat16",
                                   "float8_e4m3fn")
    <kernel>@<dtype>@n<chunk>      per-dtype AND per-client-chunk-size
                                   geometry (the chunked large-C
                                   dispatch of SchedConfig.dispatch_chunk)

`blocks_for` resolves most-specific-first and falls back to the bare
kernel key.  (Before the suffixed keys existed, lookups keyed on the
kernel name alone, so mixed-dtype runs in one process reused whatever
geometry was committed for fp32 — the per-dtype winners recorded by
``tools/autotune_kernels.py --dtype`` were unreachable.)
"""
from __future__ import annotations

import functools
import json
import os
from typing import Dict, Optional, Tuple

import numpy as np

#: safe fallback tile (the historical fixed BLOCK_R/BLOCK_C)
DEFAULT_BLOCK_R = 256
DEFAULT_BLOCK_C = 1024
#: safe fallback client-axis block: one client per grid step — the
#: geometry the vmapped per-client launches always had
DEFAULT_BLOCK_N = 1

#: the committed tuning table (next to this module)
TUNING_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "tuning.json")

_FIELDS = ("block_n", "block_r", "block_c")


def _valid_entry(e) -> bool:
    return (isinstance(e, dict)
            and all(isinstance(e.get(f, 1), int) and e.get(f, 1) >= 1
                    for f in _FIELDS))


@functools.lru_cache(maxsize=8)
def load_tuning(path: Optional[str] = None) -> Dict[str, Dict[str, int]]:
    """The committed tuning entries, `{}` on any read/parse problem
    (missing file, bad JSON, wrong version) — the kernels then run on
    the safe defaults.  Cached per process; block resolution happens
    at trace time only."""
    p = path or TUNING_PATH
    try:
        with open(p) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(data, dict) or data.get("version") != 1:
        return {}
    entries = data.get("entries")
    if not isinstance(entries, dict):
        return {}
    return {k: v for k, v in entries.items() if _valid_entry(v)}


def _dtype_name(dtype) -> Optional[str]:
    """Canonical dtype-suffix name of a tuning key (None when no dtype
    was supplied).  Goes through numpy — ml_dtypes registers the fp8
    and bf16 formats with it, so this module stays jax-free."""
    if dtype is None:
        return None
    return np.dtype(dtype).name


def _lookup(kernel: str, dtype, n: int) -> Dict[str, int]:
    """Most-specific-first entry resolution:
    ``<kernel>@<dtype>@n<n>`` -> ``<kernel>@<dtype>`` -> ``<kernel>``.
    Keying on the kernel name alone (the pre-suffix behaviour) made
    mixed-dtype runs reuse one geometry for every dtype and chunk
    size."""
    table = load_tuning()
    name = _dtype_name(dtype)
    if name is not None:
        for key in (f"{kernel}@{name}@n{int(n)}", f"{kernel}@{name}"):
            if key in table:
                return table[key]
    return table.get(kernel, {})


def blocks_for(kernel: str, n: int, r: int, c: int,
               override: Optional[Tuple[int, int, int]] = None,
               dtype=None) -> Tuple[int, int, int]:
    """Resolve the (bn, br, bc) block of a batched launch over an
    (n, r, c) stack: the explicit ``override`` (the autotuner's sweep
    hook) wins, then the most specific committed ``tuning.json`` entry
    for (``kernel``, ``dtype``, client count ``n``), then the safe
    defaults; always clamped to the operand dims.  ``dtype`` is the
    primary operand's storage dtype (the resident state the kernel
    loads) — omit it to resolve the dtype-agnostic entry."""
    if override is not None:
        bn, br, bc = override
    else:
        e = _lookup(kernel, dtype, n)
        bn = e.get("block_n", DEFAULT_BLOCK_N)
        br = e.get("block_r", DEFAULT_BLOCK_R)
        bc = e.get("block_c", DEFAULT_BLOCK_C)
    return (max(1, min(int(bn), n)), max(1, min(int(br), r)),
            max(1, min(int(bc), c)))


def blocks_2d(kernel: str, r: int, c: int,
              override: Optional[Tuple[int, int]] = None,
              dtype=None) -> Tuple[int, int]:
    """(br, bc) for an unbatched (r, c) launch of ``kernel`` — the 2D
    slice of the same tuning entry (per-dtype when ``dtype`` is
    given)."""
    if override is not None:
        br, bc = override
        return max(1, min(int(br), r)), max(1, min(int(bc), c))
    _, br, bc = blocks_for(kernel, 1, r, c, dtype=dtype)
    return br, bc
