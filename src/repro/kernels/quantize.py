"""Fused compress->decompress ("wire round-trip") Pallas TPU kernels.

The comm layer simulates the uplink in-graph: quantize the packed
(rows, cols) delta buffer and immediately dequantize it, because the
server-side aggregation consumes the *reconstruction*.  Left to XLA the
round-trip is ~5 HBM-bound elementwise ops (scale-div, add-noise, floor,
clip, scale-mul); fusing them reads each input stream once and writes
the reconstruction once — the same HBM-roofline argument as
`sophia_update`.

Layout matches `repro.comm.flat`: (rows, cols) tiles, one quantization
scale per row.  Stochastic-rounding noise is generated outside the
kernel with `jax.random` and streamed in, so the reference path
(`repro.kernels.ref`) sees the identical noise and the Pallas-vs-ref
equivalence is exact; `interpret=True` runs the kernel body on CPU
(this container), pass False on a real TPU.

Dtype contract (`CommConfig.state_dtype`): the state tiles (model /
replica / EF streams) may be stored bf16 — every kernel upcasts its
loads to fp32, computes in fp32, and stores each output in that
output's declared dtype (the first state input's dtype), so a bf16
resident buffer costs half the HBM traffic without changing the
arithmetic.  Noise and scales are always fp32.  With fp32 inputs the
casts are no-ops and the kernels are bit-identical to their pre-dtype
versions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256
BLOCK_C = 1024


def _grid_specs(R, C):
    br, bc = min(BLOCK_R, R), min(BLOCK_C, C)
    grid = (pl.cdiv(R, br), pl.cdiv(C, bc))
    tile = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    rowcol = pl.BlockSpec((br, 1), lambda i, j: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return grid, tile, rowcol, scalar


# ------------------------------------------------- stochastic quantization
def _quant_kernel(x_ref, u_ref, s_ref, out_ref, *, qmax):
    """q = clip(floor(x/scale + u), ±qmax); out = q * scale (one pass).
    Loads upcast to fp32, the store downcasts to the output dtype."""
    s = s_ref[...]                                   # (br, 1) row scales
    safe = jnp.where(s > 0, s, 1.0)
    q = jnp.floor(x_ref[...].astype(jnp.float32) / safe + u_ref[...])
    q = jnp.clip(q, -qmax, qmax)
    out_ref[...] = (q * s).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("qmax", "interpret"))
def quant_roundtrip_flat(x, noise, scale, *, qmax: int,
                         interpret: bool = True):
    """Fused stochastic quantize->dequantize over a (R, C) fp32 buffer.

    noise: U[0,1) fp32 array of x.shape; scale: (R, 1) fp32 per-row
    scales.  Returns the dequantized reconstruction (R, C) in ``x``'s
    dtype (fp32 compute in-kernel; see the module dtype contract).
    """
    R, C = x.shape
    grid, tile, rowcol, _ = _grid_specs(R, C)
    return pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[tile, tile, rowcol],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, noise, scale)


# ---------------------------------------------- fused downlink broadcast
def _broadcast_kernel(t_ref, r_ref, e_ref, u_ref, s_ref, m_ref, d_ref,
                      *, qmax):
    """Delta-code + stochastic quant round-trip + apply + residual:
    d = (theta - ref) + ef; xhat = clip(floor(d/s + u)) * s;
    model' = ref + xhat; resid' = d - xhat — one pass over 4 streams
    instead of the ~8 HBM-bound elementwise ops XLA would emit.
    Loads upcast to fp32, stores downcast to each output's dtype."""
    s = s_ref[...]
    safe = jnp.where(s > 0, s, 1.0)
    t = t_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    d = (t - r) + e_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.floor(d / safe + u_ref[...]), -qmax, qmax)
    xhat = q * s
    m_ref[...] = (r + xhat).astype(m_ref.dtype)
    d_ref[...] = (d - xhat).astype(d_ref.dtype)


@functools.partial(jax.jit, static_argnames=("qmax", "interpret"))
def broadcast_roundtrip_flat(theta, ref, ef, noise, scale, *, qmax: int,
                             interpret: bool = True):
    """Fused downlink step over (R, C) fp32 buffers (see
    `repro.comm.downlink.broadcast`).

    theta: packed server model; ref: the client's last-received model;
    ef: server-side EF residual (zeros when EF is off); noise: U[0,1)
    of theta.shape; scale: (R, 1) per-row scales of the corrected
    delta.  Returns (new client model, new EF residual).
    """
    R, C = theta.shape
    grid, tile, rowcol, _ = _grid_specs(R, C)
    return pl.pallas_call(
        functools.partial(_broadcast_kernel, qmax=qmax),
        grid=grid,
        in_specs=[tile, tile, tile, tile, rowcol],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((R, C), theta.dtype),
                   jax.ShapeDtypeStruct((R, C), theta.dtype)],
        interpret=interpret,
    )(theta, ref, ef, noise, scale)


# ------------------------------------------------ fused uplink encode
def _uplink_kernel(t_ref, s_ref, e_ref, u_ref, sc_ref, x_ref, r_ref,
                   *, qmax):
    """Delta-code + EF + stochastic quant round-trip + residual:
    d = (theta_i - theta_i^rx) + ef; xhat = clip(floor(d/s + u)) * s;
    resid' = d - xhat — the uplink twin of `_broadcast_kernel`, one
    VMEM pass over 3 input streams instead of the subtract/add/quant
    chain XLA would emit.  Loads upcast to fp32, stores downcast to
    each output's dtype."""
    sc = sc_ref[...]
    safe = jnp.where(sc > 0, sc, 1.0)
    d = (t_ref[...].astype(jnp.float32) - s_ref[...].astype(jnp.float32)
         + e_ref[...].astype(jnp.float32))
    q = jnp.clip(jnp.floor(d / safe + u_ref[...]), -qmax, qmax)
    xhat = q * sc
    x_ref[...] = xhat.astype(x_ref.dtype)
    r_ref[...] = (d - xhat).astype(r_ref.dtype)


@functools.partial(jax.jit, static_argnames=("qmax", "interpret"))
def uplink_roundtrip_flat(theta, start, ef, noise, scale, *, qmax: int,
                          interpret: bool = True):
    """Fused uplink encode over (R, C) fp32 buffers (see
    `repro.comm.compressors.Compressor.encode_delta`).

    theta: the client's locally-trained packed model; start: the packed
    model it trained from (its received replica); ef: client-side EF
    residual (zeros when EF is off); noise: U[0,1) of theta.shape;
    scale: (R, 1) per-row scales of the corrected delta.  Returns
    (decoded wire reconstruction, new EF residual).
    """
    R, C = theta.shape
    grid, tile, rowcol, _ = _grid_specs(R, C)
    return pl.pallas_call(
        functools.partial(_uplink_kernel, qmax=qmax),
        grid=grid,
        in_specs=[tile, tile, tile, tile, rowcol],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((R, C), theta.dtype),
                   jax.ShapeDtypeStruct((R, C), theta.dtype)],
        interpret=interpret,
    )(theta, start, ef, noise, scale)


# --------------------------------------------------------------- sign sgd
def _sign_kernel(x_ref, f_ref, out_ref):
    out_ref[...] = (f_ref[0, 0]
                    * jnp.sign(x_ref[...].astype(jnp.float32))
                    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sign_roundtrip_flat(x, scale, *, interpret: bool = True):
    """out = scale * sign(x); scale is a traced scalar."""
    R, C = x.shape
    grid, tile, _, scalar = _grid_specs(R, C)
    flags = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _sign_kernel,
        grid=grid,
        in_specs=[tile, scalar],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, flags)


# ------------------------------------------------------ top-k sparsify
def _thresh_kernel(x_ref, f_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.where(jnp.abs(x) >= f_ref[0, 0], x,
                             0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_threshold_flat(x, thr, *, interpret: bool = True):
    """Magnitude sparsifier: keep x where |x| >= thr (the k-th largest
    magnitude, computed outside), zero elsewhere."""
    R, C = x.shape
    grid, tile, _, scalar = _grid_specs(R, C)
    flags = jnp.asarray(thr, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _thresh_kernel,
        grid=grid,
        in_specs=[tile, scalar],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, flags)
