"""Fused compress->decompress ("wire round-trip") Pallas TPU kernels.

The comm layer simulates the uplink in-graph: quantize the packed
(rows, cols) delta buffer and immediately dequantize it, because the
server-side aggregation consumes the *reconstruction*.  Left to XLA the
round-trip is ~5 HBM-bound elementwise ops (scale-div, add-noise, floor,
clip, scale-mul); fusing them reads each input stream once and writes
the reconstruction once — the same HBM-roofline argument as
`sophia_update`.

Layout matches `repro.comm.flat`: (rows, cols) tiles, one quantization
scale per row.  Stochastic-rounding noise is generated outside the
kernel with `jax.random` and streamed in, so the reference path
(`repro.kernels.ref`) sees the identical noise and the Pallas-vs-ref
equivalence is exact; `interpret=True` runs the kernel body on CPU
(this container), pass False on a real TPU.

Dtype contract (`CommConfig.state_dtype` / `moment_dtype` /
`hessian_dtype`): the state tiles (model / replica / EF streams) may
be stored in a narrower resident format — bf16, or the fp8 formats
float8_e4m3fn / float8_e5m2 — and every kernel upcasts its loads to
fp32 in VMEM, computes in fp32, and stores each output in that
output's declared dtype (the first state input's dtype), so a bf16
buffer costs half and an fp8 buffer a quarter of the fp32 HBM traffic
without changing the arithmetic.  Noise and scales are always fp32.
With fp32 inputs the casts are no-ops and the kernels are
bit-identical to their pre-dtype versions.  Launch geometry resolves
per (kernel, storage dtype, client-chunk size) through
`repro.kernels.tuning`.

Client batching: each round-trip also has a ``*_batched`` entry point
over the packed (N, rows, cols) client stack — ONE launch with a
leading client grid dimension instead of N per-client launches.  The
batched launches reuse the same elementwise kernel bodies over 3D
blocks, so they are bitwise equal to the looped per-client results
(tests/test_kernel_conformance.py).  Block shapes come from the
committed `repro.kernels.tuning` table (``blocks=`` overrides, for
the autotuner sweep).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning

BLOCK_R = 256
BLOCK_C = 1024


def _grid_specs(R, C, kernel="quant_roundtrip", dtype=None):
    br, bc = tuning.blocks_2d(kernel, R, C, dtype=dtype)
    grid = (pl.cdiv(R, br), pl.cdiv(C, bc))
    tile = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    rowcol = pl.BlockSpec((br, 1), lambda i, j: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return grid, tile, rowcol, scalar


def _grid_specs3(N, R, C, kernel, blocks, dtype=None):
    """Launch geometry of a client-batched (N, R, C) kernel: the grid
    gains a leading client axis; ``shared2`` maps an unbatched (R, C)
    operand (e.g. the one server model every client receives) into the
    same (br, bc) block for every client grid step, where the kernel
    body broadcasts it against the (bn, br, bc) stacks.  ``dtype`` is
    the primary state operand's storage dtype — the tuning table may
    commit per-dtype / per-chunk-size winners."""
    bn, br, bc = tuning.blocks_for(kernel, N, R, C, override=blocks,
                                   dtype=dtype)
    grid = (pl.cdiv(N, bn), pl.cdiv(R, br), pl.cdiv(C, bc))
    tile3 = pl.BlockSpec((bn, br, bc), lambda n, i, j: (n, i, j))
    rowcol3 = pl.BlockSpec((bn, br, 1), lambda n, i, j: (n, i, 0))
    client3 = pl.BlockSpec((bn, 1, 1), lambda n, i, j: (n, 0, 0))
    shared2 = pl.BlockSpec((br, bc), lambda n, i, j: (i, j))
    return grid, tile3, rowcol3, client3, shared2


# ------------------------------------------------- stochastic quantization
def _quant_kernel(x_ref, u_ref, s_ref, out_ref, *, qmax):
    """q = clip(floor(x/scale + u), ±qmax); out = q * scale (one pass).
    Loads upcast to fp32, the store downcasts to the output dtype."""
    s = s_ref[...]                                   # (br, 1) row scales
    safe = jnp.where(s > 0, s, 1.0)
    q = jnp.floor(x_ref[...].astype(jnp.float32) / safe + u_ref[...])
    q = jnp.clip(q, -qmax, qmax)
    out_ref[...] = (q * s).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("qmax", "interpret"))
def quant_roundtrip_flat(x, noise, scale, *, qmax: int,
                         interpret: bool = True):
    """Fused stochastic quantize->dequantize over a (R, C) fp32 buffer.

    noise: U[0,1) fp32 array of x.shape; scale: (R, 1) fp32 per-row
    scales.  Returns the dequantized reconstruction (R, C) in ``x``'s
    dtype (fp32 compute in-kernel; see the module dtype contract).
    """
    R, C = x.shape
    grid, tile, rowcol, _ = _grid_specs(R, C, dtype=x.dtype)
    return pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[tile, tile, rowcol],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, noise, scale)


# ---------------------------------------------- fused downlink broadcast
def _broadcast_kernel(t_ref, r_ref, e_ref, u_ref, s_ref, m_ref, d_ref,
                      *, qmax):
    """Delta-code + stochastic quant round-trip + apply + residual:
    d = (theta - ref) + ef; xhat = clip(floor(d/s + u)) * s;
    model' = ref + xhat; resid' = d - xhat — one pass over 4 streams
    instead of the ~8 HBM-bound elementwise ops XLA would emit.
    Loads upcast to fp32, stores downcast to each output's dtype."""
    s = s_ref[...]
    safe = jnp.where(s > 0, s, 1.0)
    t = t_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    d = (t - r) + e_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.floor(d / safe + u_ref[...]), -qmax, qmax)
    xhat = q * s
    m_ref[...] = (r + xhat).astype(m_ref.dtype)
    d_ref[...] = (d - xhat).astype(d_ref.dtype)


@functools.partial(jax.jit, static_argnames=("qmax", "interpret"))
def broadcast_roundtrip_flat(theta, ref, ef, noise, scale, *, qmax: int,
                             interpret: bool = True):
    """Fused downlink step over (R, C) fp32 buffers (see
    `repro.comm.downlink.broadcast`).

    theta: packed server model; ref: the client's last-received model;
    ef: server-side EF residual (zeros when EF is off); noise: U[0,1)
    of theta.shape; scale: (R, 1) per-row scales of the corrected
    delta.  Returns (new client model, new EF residual).
    """
    R, C = theta.shape
    grid, tile, rowcol, _ = _grid_specs(R, C, "broadcast_roundtrip",
                                        dtype=theta.dtype)
    return pl.pallas_call(
        functools.partial(_broadcast_kernel, qmax=qmax),
        grid=grid,
        in_specs=[tile, tile, tile, tile, rowcol],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((R, C), theta.dtype),
                   jax.ShapeDtypeStruct((R, C), theta.dtype)],
        interpret=interpret,
    )(theta, ref, ef, noise, scale)


# ------------------------------------------------ fused uplink encode
def _uplink_kernel(t_ref, s_ref, e_ref, u_ref, sc_ref, x_ref, r_ref,
                   *, qmax):
    """Delta-code + EF + stochastic quant round-trip + residual:
    d = (theta_i - theta_i^rx) + ef; xhat = clip(floor(d/s + u)) * s;
    resid' = d - xhat — the uplink twin of `_broadcast_kernel`, one
    VMEM pass over 3 input streams instead of the subtract/add/quant
    chain XLA would emit.  Loads upcast to fp32, stores downcast to
    each output's dtype."""
    sc = sc_ref[...]
    safe = jnp.where(sc > 0, sc, 1.0)
    d = (t_ref[...].astype(jnp.float32) - s_ref[...].astype(jnp.float32)
         + e_ref[...].astype(jnp.float32))
    q = jnp.clip(jnp.floor(d / safe + u_ref[...]), -qmax, qmax)
    xhat = q * sc
    x_ref[...] = xhat.astype(x_ref.dtype)
    r_ref[...] = (d - xhat).astype(r_ref.dtype)


@functools.partial(jax.jit, static_argnames=("qmax", "interpret"))
def uplink_roundtrip_flat(theta, start, ef, noise, scale, *, qmax: int,
                          interpret: bool = True):
    """Fused uplink encode over (R, C) fp32 buffers (see
    `repro.comm.compressors.Compressor.encode_delta`).

    theta: the client's locally-trained packed model; start: the packed
    model it trained from (its received replica); ef: client-side EF
    residual (zeros when EF is off); noise: U[0,1) of theta.shape;
    scale: (R, 1) per-row scales of the corrected delta.  Returns
    (decoded wire reconstruction, new EF residual).
    """
    R, C = theta.shape
    grid, tile, rowcol, _ = _grid_specs(R, C, "uplink_roundtrip",
                                        dtype=theta.dtype)
    return pl.pallas_call(
        functools.partial(_uplink_kernel, qmax=qmax),
        grid=grid,
        in_specs=[tile, tile, tile, tile, rowcol],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((R, C), theta.dtype),
                   jax.ShapeDtypeStruct((R, C), theta.dtype)],
        interpret=interpret,
    )(theta, start, ef, noise, scale)


# --------------------------------------------------------------- sign sgd
def _sign_kernel(x_ref, f_ref, out_ref):
    out_ref[...] = (f_ref[0, 0]
                    * jnp.sign(x_ref[...].astype(jnp.float32))
                    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sign_roundtrip_flat(x, scale, *, interpret: bool = True):
    """out = scale * sign(x); scale is a traced scalar."""
    R, C = x.shape
    grid, tile, _, scalar = _grid_specs(R, C, "sign_roundtrip",
                                        dtype=x.dtype)
    flags = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _sign_kernel,
        grid=grid,
        in_specs=[tile, scalar],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, flags)


# ------------------------------------------------------ top-k sparsify
def _thresh_kernel(x_ref, f_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.where(jnp.abs(x) >= f_ref[0, 0], x,
                             0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_threshold_flat(x, thr, *, interpret: bool = True):
    """Magnitude sparsifier: keep x where |x| >= thr (the k-th largest
    magnitude, computed outside), zero elsewhere."""
    R, C = x.shape
    grid, tile, _, scalar = _grid_specs(R, C, "topk_threshold",
                                        dtype=x.dtype)
    flags = jnp.asarray(thr, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _thresh_kernel,
        grid=grid,
        in_specs=[tile, scalar],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, flags)


# -------------------------------------------- client-batched launches
#
# One pallas_call over the packed (N, R, C) client stack.  The 2D
# kernel bodies above are elementwise with numpy broadcasting, so
# feeding them (bn, br, bc) blocks computes the identical value per
# coordinate — batched == looped per-client bitwise by construction.


@functools.partial(jax.jit, static_argnames=("qmax", "interpret",
                                             "blocks"))
def quant_roundtrip_batched(x, noise, scale, *, qmax: int,
                            interpret: bool = True, blocks=None):
    """`quant_roundtrip_flat` over an (N, R, C) client stack in one
    launch.  scale: (N, R, 1) per-client per-row scales; blocks: an
    optional static (bn, br, bc) override of the tuned geometry."""
    N, R, C = x.shape
    grid, tile3, rowcol3, _, _ = _grid_specs3(
        N, R, C, "quant_roundtrip", blocks, dtype=x.dtype)
    return pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[tile3, tile3, rowcol3],
        out_specs=tile3,
        out_shape=jax.ShapeDtypeStruct((N, R, C), x.dtype),
        interpret=interpret,
    )(x, noise, scale)


@functools.partial(jax.jit, static_argnames=("qmax", "interpret",
                                             "blocks"))
def broadcast_roundtrip_batched(theta, ref, ef, noise, scale, *,
                                qmax: int, interpret: bool = True,
                                blocks=None):
    """`broadcast_roundtrip_flat` over (N, R, C) per-client replica /
    EF stacks in one launch.  theta may stay (R, C) — the one server
    model is shared across the client grid axis (broadcast in-VMEM)
    — or be a (N, R, C) stack; scale: (N, R, 1)."""
    N, R, C = ref.shape
    grid, tile3, rowcol3, _, shared2 = _grid_specs3(
        N, R, C, "broadcast_roundtrip", blocks, dtype=theta.dtype)
    t_spec = shared2 if theta.ndim == 2 else tile3
    return pl.pallas_call(
        functools.partial(_broadcast_kernel, qmax=qmax),
        grid=grid,
        in_specs=[t_spec, tile3, tile3, tile3, rowcol3],
        out_specs=[tile3, tile3],
        out_shape=[jax.ShapeDtypeStruct((N, R, C), theta.dtype),
                   jax.ShapeDtypeStruct((N, R, C), theta.dtype)],
        interpret=interpret,
    )(theta, ref, ef, noise, scale)


@functools.partial(jax.jit, static_argnames=("qmax", "interpret",
                                             "blocks"))
def uplink_roundtrip_batched(theta, start, ef, noise, scale, *,
                             qmax: int, interpret: bool = True,
                             blocks=None):
    """`uplink_roundtrip_flat` over (N, R, C) locally-trained client
    stacks in one launch.  start may stay (R, C) — every client
    trained from the same broadcast model (downlink replicas off) —
    or be a (N, R, C) per-client replica stack; scale: (N, R, 1)."""
    N, R, C = theta.shape
    grid, tile3, rowcol3, _, shared2 = _grid_specs3(
        N, R, C, "uplink_roundtrip", blocks, dtype=theta.dtype)
    s_spec = shared2 if start.ndim == 2 else tile3
    return pl.pallas_call(
        functools.partial(_uplink_kernel, qmax=qmax),
        grid=grid,
        in_specs=[tile3, s_spec, tile3, tile3, rowcol3],
        out_specs=[tile3, tile3],
        out_shape=[jax.ShapeDtypeStruct((N, R, C), theta.dtype),
                   jax.ShapeDtypeStruct((N, R, C), theta.dtype)],
        interpret=interpret,
    )(theta, start, ef, noise, scale)


def _sign_kernel_batched(x_ref, f_ref, out_ref):
    # per-client scale block (bn, 1, 1) broadcasts over (bn, br, bc)
    out_ref[...] = (f_ref[...]
                    * jnp.sign(x_ref[...].astype(jnp.float32))
                    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "blocks"))
def sign_roundtrip_batched(x, scale, *, interpret: bool = True,
                           blocks=None):
    """`sign_roundtrip_flat` over an (N, R, C) stack in one launch;
    scale: (N,) per-client scales."""
    N, R, C = x.shape
    grid, tile3, _, client3, _ = _grid_specs3(
        N, R, C, "sign_roundtrip", blocks, dtype=x.dtype)
    flags = jnp.asarray(scale, jnp.float32).reshape(N, 1, 1)
    return pl.pallas_call(
        _sign_kernel_batched,
        grid=grid,
        in_specs=[tile3, client3],
        out_specs=tile3,
        out_shape=jax.ShapeDtypeStruct((N, R, C), x.dtype),
        interpret=interpret,
    )(x, flags)


def _thresh_kernel_batched(x_ref, f_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.where(jnp.abs(x) >= f_ref[...], x,
                             0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "blocks"))
def topk_threshold_batched(x, thr, *, interpret: bool = True,
                           blocks=None):
    """`topk_threshold_flat` over an (N, R, C) stack in one launch;
    thr: (N,) per-client magnitude thresholds."""
    N, R, C = x.shape
    grid, tile3, _, client3, _ = _grid_specs3(
        N, R, C, "topk_threshold", blocks, dtype=x.dtype)
    flags = jnp.asarray(thr, jnp.float32).reshape(N, 1, 1)
    return pl.pallas_call(
        _thresh_kernel_batched,
        grid=grid,
        in_specs=[tile3, client3],
        out_specs=tile3,
        out_shape=jax.ShapeDtypeStruct((N, R, C), x.dtype),
        interpret=interpret,
    )(x, flags)
