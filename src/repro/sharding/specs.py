"""PartitionSpec rules for params / batches / caches.

Single tensor-parallel axis ("model", 16) + data axes ("data" or
("pod","data")). A dim is sharded only when divisible by the axis size;
otherwise the rule falls through (DESIGN.md §7 documents the fallback
consequences, which the roofline table surfaces).
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import FedConfig, ModelConfig, ShapeConfig

MODEL_AXIS = "model"

# leaf-name -> which matmul dim prefers the model axis
_LAST_DIM = {"wq", "wk", "wv", "w_up", "w_gate", "w_in", "w_gate_in",
             "w_dkv", "w_ukv", "w_gates", "w_up_gate", "lm_head", "w_a",
             "w_x"}
_FIRST_DIM = {"wo", "w_down", "w_out"}
_REPLICATED = {"router", "conv_w", "conv1", "conv2", "r_gates", "lam",
               "b_a", "b_x", "b_gates", "b_if", "w_if", "fc", "gn"}


def _axis_prod(mesh: Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    return int(np.prod([mesh.shape[n] for n in names]))


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_spec_fn(cfg: ModelConfig, mesh: Mesh, *,
                  fsdp_axes: Optional[Tuple[str, ...]] = None):
    """Returns fn(path, leaf) -> PartitionSpec for SERVER model params."""
    msize = _axis_prod(mesh, MODEL_AXIS)
    fsize = _axis_prod(mesh, fsdp_axes) if fsdp_axes else 0

    def spec(path, leaf):
        pstr = _path_str(path)
        name = pstr.split("/")[-1]
        shape = leaf.shape
        nd = len(shape)
        dims: list = [None] * nd

        def try_shard(dim, axis, size):
            if dim is not None and 0 <= dim < nd and dims[dim] is None \
                    and axis not in [d for d in dims if d] \
                    and shape[dim] % size == 0 and shape[dim] >= size:
                dims[dim] = axis
                return True
            return False

        model_dim = None
        if name in _REPLICATED or nd == 0:
            pass
        elif name == "embed":
            model_dim = 0
        elif cfg.moe is not None and "ffn" in pstr and "shared" not in pstr \
                and name in ("w_gate", "w_up", "w_down") and nd >= 3:
            model_dim = nd - 3          # expert dim
        elif name in _LAST_DIM:
            model_dim = nd - 1
        elif name in _FIRST_DIM:
            model_dim = nd - 2
        elif nd >= 2:
            model_dim = int(np.argmax(shape))     # generic fallback

        if model_dim is not None:
            ok = try_shard(model_dim, MODEL_AXIS, msize)
            if not ok and nd >= 2:
                # alternate matmul dim
                alt = nd - 1 if model_dim != nd - 1 else nd - 2
                try_shard(alt, MODEL_AXIS, msize)

        if fsdp_axes and nd >= 2 and name not in _REPLICATED:
            # shard one remaining dim over the data axes (FSDP / ZeRO-3)
            order = [nd - 2, nd - 1, 0]
            for d in order:
                if dims[d] is None and try_shard(d, fsdp_axes, fsize):
                    break
        return P(*dims)

    return spec


def param_shardings(cfg: ModelConfig, mesh: Mesh, params, *,
                    fsdp_axes=None, client_axes=None):
    """NamedSharding pytree. client_axes: leading client dim (opt states /
    per-client params in the parallel strategy)."""
    fn = param_spec_fn(cfg, mesh, fsdp_axes=fsdp_axes)

    def one(path, leaf):
        spec = fn(path, leaf)
        if client_axes is not None:
            spec = P(client_axes, *spec)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_spec(mesh: Mesh, *, client_axes=None, batch_axes=("data",)):
    """Specs for input batches. With client_axes set, leaves are (C, b, ...)
    and C shards over the client axes; otherwise dim0 is the global batch."""
    lead = client_axes if client_axes is not None else batch_axes

    def spec(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        size = _axis_prod(mesh, lead)
        if leaf.shape[0] % size == 0 and leaf.shape[0] >= size:
            return NamedSharding(mesh, P(lead, *([None] * (nd - 1))))
        # M-RoPE positions (3,B,S) and tiny leading dims: try dim1
        if nd >= 2 and leaf.shape[1] % size == 0 and leaf.shape[1] >= size:
            return NamedSharding(mesh, P(None, lead, *([None] * (nd - 2))))
        return NamedSharding(mesh, P(*([None] * nd)))

    return spec


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache, *,
                    batch_axes=("data",)):
    """KV/recurrent cache sharding for serving.

    batch -> data axes when divisible; else (batch==1 long-context) the
    sequence/window dim -> data. kv-heads -> model when divisible, else
    head_dim -> model.
    """
    bsize = _axis_prod(mesh, batch_axes)
    msize = _axis_prod(mesh, MODEL_AXIS)

    def spec(path, leaf):
        pstr = _path_str(path)
        name = pstr.split("/")[-1]
        shape = leaf.shape
        nd = len(shape)
        stacked = pstr.split("/")[0].startswith("g")  # leading scan-rep dim
        off = 1 if stacked else 0
        dims = [None] * nd
        bdim = off  # batch dim

        def put(dim, axis, size):
            if dim < nd and dims[dim] is None and shape[dim] % size == 0 \
                    and shape[dim] >= size:
                dims[dim] = axis
                return True
            return False

        if name in ("k", "v"):           # (B, S, K, hd)
            if not put(bdim, batch_axes, bsize):
                put(bdim + 1, batch_axes, bsize)          # seq over data
            if not put(bdim + 2, MODEL_AXIS, msize):      # kv heads
                put(bdim + 3, MODEL_AXIS, msize)          # head_dim
        elif name in ("ckv", "kpe"):     # (B, S, rank)
            if not put(bdim, batch_axes, bsize):
                put(bdim + 1, batch_axes, bsize)
            put(bdim + 2, MODEL_AXIS, msize)
        elif name in ("state",):         # (B, W)
            put(bdim, batch_axes, bsize)
            put(bdim + 1, MODEL_AXIS, msize)
        elif name == "C":                # (B, H, dk, dv)
            put(bdim, batch_axes, bsize)
            put(bdim + 3, MODEL_AXIS, msize)
        elif name in ("n", "h", "c", "m"):
            put(bdim, batch_axes, bsize)
        elif name == "conv":             # (B, cw-1, W)
            put(bdim, batch_axes, bsize)
            put(bdim + 2, MODEL_AXIS, msize)
        else:
            put(bdim, batch_axes, bsize)
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(spec, cache)
