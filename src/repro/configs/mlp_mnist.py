"""The paper's MLP model (MNIST/FMNIST experiments, §V)."""
MODEL_KIND = "mlp"
HIDDEN = 128
