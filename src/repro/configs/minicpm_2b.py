"""minicpm-2b [dense] — 40L d_model=2304 36H (MHA) d_ff=5760 vocab=122753.
WSD schedule, depth-scaled residuals (1.4/sqrt(L)), scale_emb=12.
[arXiv:2404.06395]"""
import math
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753, ffn_kind="swiglu",
    residual_scale=1.4 / math.sqrt(40), scale_emb=12.0,
    tie_embeddings=True, dtype="bfloat16",
)
FED = dict(strategy="parallel", schedule="wsd")
CITATION = "[arXiv:2404.06395]"
