"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680.
RG-LRU + local attention 2:1 (pattern rec,rec,local x8 + rec,rec).
[arXiv:2402.19427]"""
import math
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000, block_pattern=("rec", "rec", "local"),
    window=2048, lru_width=2560, ffn_kind="geglu",
    scale_emb=math.sqrt(2560.0), tie_embeddings=True, dtype="bfloat16",
)
FED = dict(strategy="parallel")
CITATION = "[arXiv:2402.19427]"
