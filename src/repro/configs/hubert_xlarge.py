"""hubert-xlarge [audio] — 48L d_model=1280 16H d_ff=5120 vocab=504.
Encoder-only (bidirectional); conv feature frontend stubbed —
input_specs provides frame embeddings. No decode shapes (DESIGN.md §5).
[arXiv:2106.07447]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504, causal=False, ffn_kind="gelu",
    tie_embeddings=False, embedding_inputs=True, dtype="bfloat16",
)
FED = dict(strategy="parallel")
CITATION = "[arXiv:2106.07447]"
