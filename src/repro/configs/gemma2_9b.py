"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000. Alternating local(4096)/global attention, logit softcaps,
GeGLU, post-norms. [arXiv:2408.00118]"""
import math
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000, block_pattern=("local", "global"),
    window=4096, softcap_attn=50.0, softcap_final=30.0, post_norm=True,
    ffn_kind="geglu", scale_emb=math.sqrt(3584.0),
    tie_embeddings=True, dtype="bfloat16",
)
FED = dict(strategy="sequential")
CITATION = "[arXiv:2408.00118]"
