"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024, partial rotary (the legacy 2d-RoPE layout: rotary on half
the head dims). [arXiv:2406.12793]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024, rotary_pct=0.5, ffn_kind="swiglu",
    tie_embeddings=False, dtype="bfloat16",
)
FED = dict(strategy="parallel")
CITATION = "[arXiv:2406.12793]"
