"""Config registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib

from repro.configs.base import (FedConfig, INPUT_SHAPES, MLAConfig,
                                ModelConfig, MoEConfig, RobustConfig,
                                RunConfig, ShapeConfig)

ARCH_IDS = [
    "qwen3-moe-235b-a22b",
    "minicpm-2b",
    "qwen3-14b",
    "deepseek-v2-lite-16b",
    "hubert-xlarge",
    "gemma2-9b",
    "xlstm-1.3b",
    "qwen2-vl-2b",
    "chatglm3-6b",
    "recurrentgemma-2b",
]


def _module(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_model_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_fed_overrides(arch_id: str) -> dict:
    return getattr(_module(arch_id), "FED", {})


def get_citation(arch_id: str) -> str:
    return getattr(_module(arch_id), "CITATION", "")
