"""xlstm-1.3b [ssm] — 48 blocks d_model=2048 4H, mLSTM:sLSTM 7:1.
mLSTM in chunkwise-parallel form; sLSTM sequential scan. [arXiv:2405.04517]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("m", "m", "m", "m", "m", "m", "m", "s"),
    tie_embeddings=False, dtype="bfloat16",
)
FED = dict(strategy="parallel")
CITATION = "[arXiv:2405.04517]"
