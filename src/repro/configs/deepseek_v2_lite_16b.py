"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408(expert)
vocab=102400, MLA kv_lora=512, 2 shared + 64 routed top-6.
[arXiv:2405.04434]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=192,
    d_ff=0, vocab_size=102400, ffn_kind="swiglu",
    tie_embeddings=False, dtype="bfloat16",
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared=2, d_ff_shared=1408),
)
FED = dict(strategy="sequential")
CITATION = "[arXiv:2405.04434]"
