"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE (t/h/w sections 16/24/24 of the 64 rotary slots).
ViT frontend stubbed — input_specs provides patch embeddings.
[arXiv:2409.12191]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936, mrope_sections=(16, 24, 24),
    rope_theta=1e6, ffn_kind="swiglu", tie_embeddings=True,
    embedding_inputs=True, dtype="bfloat16",
)
FED = dict(strategy="parallel")
CITATION = "[arXiv:2409.12191]"
