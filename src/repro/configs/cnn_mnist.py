"""The paper's CNN model (MNIST/FMNIST experiments, §V)."""
MODEL_KIND = "cnn"
CHANNELS = (16, 32)
