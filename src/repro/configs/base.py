"""Config dataclasses for the model zoo, federated runtime and input shapes."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 256


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # layer stacking: pattern of block kinds, tiled over num_layers.
    #   attn | local | global | rec (RG-LRU) | m (mLSTM) | s (sLSTM)
    block_pattern: Tuple[str, ...] = ("attn",)
    # attention options
    causal: bool = True
    qk_norm: bool = False
    softcap_attn: Optional[float] = None
    softcap_final: Optional[float] = None
    window: Optional[int] = None      # sliding-window size for 'local' blocks
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0           # chatglm applies rotary to half the dims
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    mla: Optional[MLAConfig] = None
    # ffn
    ffn_kind: str = "swiglu"          # swiglu | geglu | gelu
    moe: Optional[MoEConfig] = None
    # recurrent blocks
    lru_width: int = 0                # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4               # temporal conv in recurrent blocks
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    # perf knobs (§Perf hillclimb; defaults = paper-faithful baseline)
    pad_attn_heads: int = 0           # pad q-heads to this count with zero
    # wq cols / wo rows (mathematically exact — zero heads contribute 0 and
    # receive 0 gradient). Aligns num_heads to the model axis so attention
    # shards on heads instead of splitting head_dim (which turns every
    # score einsum into a partial-sum all-reduce).
    slstm_unroll: int = 1             # scan unroll: weights read once/U steps
    attn_chunk_threshold: int = 2048  # seq len above which attention uses
    # the online-softmax KV-chunked path (0 = always chunked; big = dense)
    attn_kv_chunk: int = 1024         # KV tile for the chunked path
    train_remat: bool = True          # per-block activation checkpointing
    scan_compute_dtype: str = "float32"   # mLSTM chunk-scan operand dtype:
    #   "bfloat16" keeps q/k/v bf16 across the sharding boundary (halves the
    #   per-chunk model-axis all-gather bytes); accumulation stays fp32.
    # misc
    residual_scale: float = 1.0       # minicpm depth scaling
    scale_emb: float = 1.0
    tie_embeddings: bool = True
    post_norm: bool = False           # gemma2 post-block norms
    dtype: str = "float32"
    # serving: replace 'global' with 'local' blocks for long-context mode
    long_mode_swa_only: bool = False
    # frontend stubs (audio/vlm): inputs are embeddings, not token ids
    embedding_inputs: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def vocab_padded(self) -> int:
        m = VOCAB_PAD_MULTIPLE
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def pattern_reps(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def pattern_remainder(self) -> Tuple[str, ...]:
        rem = self.num_layers % len(self.block_pattern)
        return tuple(self.block_pattern[:rem])

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                max_experts: int = 4) -> "ModelConfig":
        """Smoke-test variant: same family/features, tiny dims."""
        num_layers = max(num_layers, len(self.block_pattern))
        num_layers = (num_layers // len(self.block_pattern)) * len(self.block_pattern)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        if heads % kv:
            kv = 1
        changes = dict(
            num_layers=num_layers, d_model=d_model, num_heads=heads,
            num_kv_heads=kv, head_dim=d_model // heads,
            d_ff=max(2 * d_model, 64), vocab_size=min(self.vocab_size, 512),
            lru_width=min(self.lru_width, d_model) if self.lru_width else 0,
            window=min(self.window, 64) if self.window else None,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, max_experts),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=max(d_model // 2, 32),
                num_shared=min(self.moe.num_shared, 1),
                d_ff_shared=max(d_model // 2, 32) if self.moe.num_shared else 0)
        if self.mla is not None:
            changes["mla"] = MLAConfig(kv_lora_rank=64, qk_nope_head_dim=32,
                                       qk_rope_head_dim=16, v_head_dim=32)
            changes["head_dim"] = 32
        if self.mrope_sections is not None:
            hd = changes["head_dim"]
            changes["mrope_sections"] = (hd // 2 - 2 * (hd // 8), hd // 8, hd // 8)
        return dataclasses.replace(self, **changes)


#: The named wire streams of a federated round (see docs/wire-format.md).
#: Every stream shares the packed (rows, cols) layout of `repro.comm.flat`
#: and gets its own compressor + error-feedback policy via
#: `CommConfig.stream(name)`.
COMM_STREAMS = ("uplink", "downlink", "hessian")


@dataclass(frozen=True)
class CommConfig:
    """Client<->server communication model (repro.comm).

    The round is modelled as three named wire streams, each with an
    independent compressor (``COMM_STREAMS``):

    * ``uplink`` — the client *param-delta* (theta_i - theta_i^rx after
      local training), compressed per participant with optional
      client-side error feedback.
    * ``downlink`` — the server broadcast, as a per-client delta
      against each client's last-received model, with server-side
      per-client error feedback (``downlink_*`` fields).
    * ``hessian`` — the Hessian-EMA (Sophia ``h``) uplink plus the
      common averaged-curvature broadcast back (``hessian_*`` fields;
      ``"off"`` disables the stream entirely).

    The default — lossless identity uplink/downlink, hessian off, full
    participation — makes the round bit-identical to the direct
    client-mean path, so existing runs are untouched; any other setting
    routes the round through the delta-space
    encode/aggregate/broadcast pipeline in `FedEngine`.
    """
    compressor: str = "identity"      # identity | int8 | int4 | topk | signsgd
    # Per-client error-feedback residual (EF-SGD). "auto" materialises
    # it exactly for the biased compressors (topk, signsgd) that need it
    # to converge; True forces it for any lossy compressor (C full fp32
    # model copies of HBM); False disables it.
    error_feedback: object = "auto"   # "auto" | True | False
    participation: float = 1.0        # fraction S/C of clients sampled/round
    topk_ratio: float = 0.01          # k = ceil(ratio * n_params)
    sign_majority: bool = False       # signsgd: server majority vote on signs
    quant_block: int = 1024           # elements per quantization scale group
    use_pallas: bool = False          # fused quantize/dequantize kernels
    seed: int = 0                     # participation-sampling salt
    # ---- downlink stream (server -> client broadcast) -----------------
    # "identity" keeps the PR-1 exact fp32 broadcast (no per-client
    # model replicas allocated); any other value compresses the
    # broadcast as a delta vs each client's last-received model, with
    # server-side per-client error feedback.
    downlink_compressor: str = "identity"
    downlink_error_feedback: object = "auto"   # "auto" | True | False
    # ---- hessian stream (Sophia h-EMA uplink + averaged broadcast) ----
    # "off" disables the stream (no curvature crosses the wire). Any
    # compressor name enables curvature averaging: participants upload
    # their compressed h-EMA, the server averages and broadcasts ONE
    # common payload back. Second-order state is smoother than
    # gradients, so the intended default when enabled is "int4".
    hessian_compressor: str = "off"
    # ---- resident-state storage dtype ---------------------------------
    # Storage dtype of the wire-layout state that LIVES on device
    # between rounds (packed params, the (C, rows, cols) Sophia m/h
    # EMAs, EF residuals, downlink replicas). "bfloat16" halves the
    # resident-state HBM; every round still computes in fp32 — rows are
    # upcast when gathered and downcast when scattered back, and the
    # fused Pallas kernels carry a dtype-parameterized load/store path.
    # Wire payloads are unaffected (bytes on the wire follow the
    # compressor, not this dtype).
    state_dtype: str = "float32"      # float32 | bfloat16 | float8_e4m3fn | float8_e5m2
    # Per-buffer overrides of state_dtype for the two largest resident
    # stacks, the (C, rows, cols) Sophia EMAs: moment_dtype stores m,
    # hessian_dtype stores h. "" inherits state_dtype. The fp8 formats
    # (float8_e4m3fn for m — more mantissa; float8_e5m2 for h — more
    # range) cut the dominant resident-state HBM to 0.25x of fp32;
    # compute still upcasts to fp32 in-kernel, so only one store
    # rounding per round is added per buffer.
    moment_dtype: str = ""            # "" -> inherit state_dtype
    hessian_dtype: str = ""           # "" -> inherit state_dtype
    # ---- per-stream packing geometry overrides (0/0.0 = inherit) ------
    # Each stream may override the quantization group size and top-k
    # sparsity of its packed layout: curvature is much smoother than
    # gradients, so the hessian stream typically affords coarser groups
    # (fewer fp32 scales on the wire).  The stream's (rows, cols) wire
    # layout follows its own quant_block, so streams may disagree on
    # geometry; they always share the flattened `total` coordinates.
    downlink_quant_block: int = 0     # 0 -> inherit quant_block
    downlink_topk_ratio: float = 0.0  # 0.0 -> inherit topk_ratio
    hessian_quant_block: int = 0      # 0 -> inherit quant_block
    hessian_topk_ratio: float = 0.0   # 0.0 -> inherit topk_ratio

    @property
    def lossless(self) -> bool:
        return self.compressor == "identity"

    @property
    def downlink_enabled(self) -> bool:
        return self.downlink_compressor != "identity"

    @property
    def hessian_enabled(self) -> bool:
        return self.hessian_compressor != "off"

    @property
    def multi_stream(self) -> bool:
        """Any stream beyond the PR-1 uplink is active."""
        return self.downlink_enabled or self.hessian_enabled

    def stream(self, name: str) -> "CommConfig":
        """Per-stream view: this config with ``compressor`` /
        ``error_feedback`` / packing geometry (``quant_block``,
        ``topk_ratio``) resolved for the named stream, so the same
        compressor factory and accounting serve every stream."""
        if name == "uplink":
            return self
        if name == "downlink":
            return dataclasses.replace(
                self, compressor=self.downlink_compressor,
                error_feedback=self.downlink_error_feedback,
                quant_block=self.downlink_quant_block or self.quant_block,
                topk_ratio=self.downlink_topk_ratio or self.topk_ratio)
        if name == "hessian":
            c = self.hessian_compressor
            return dataclasses.replace(
                self, compressor="identity" if c == "off" else c,
                error_feedback=False,
                quant_block=self.hessian_quant_block or self.quant_block,
                topk_ratio=self.hessian_topk_ratio or self.topk_ratio)
        raise ValueError(f"unknown stream {name!r} (want {COMM_STREAMS})")

    def num_participants(self, num_clients: int) -> int:
        s = int(round(self.participation * num_clients))
        return max(1, min(num_clients, s))


#: Round disciplines of the virtual-time scheduler (repro.sched).
SCHED_DISCIPLINES = ("sync", "semisync", "async")

#: Latency profiles of the virtual-time scheduler (repro.sched).
LATENCY_PROFILES = ("uniform", "straggler", "lognormal")


@dataclass(frozen=True)
class SchedConfig:
    """Virtual-time round scheduling (repro.sched).

    A deterministic event simulator assigns every client a latency
    (compute seconds per local step plus transfer seconds derived from
    the comm layer's exact per-stream byte counts and ``bandwidth_bps``)
    and drives one of three round disciplines:

    * ``sync`` — today's engine behaviour, bit-exact: every sampled
      client trains each round, the round takes as long as its slowest
      participant.
    * ``semisync`` — FedBuff-style: the server aggregates the first
      ``buffer_size`` arrivals of each round (staleness-weighted mean);
      stragglers keep training and deliver stale deltas into a later
      buffer.
    * ``async`` — every arrival is applied immediately with the
      staleness-decayed weight ``(1 + staleness)^-staleness_power``.
    """
    discipline: str = "sync"          # sync | semisync | async
    buffer_size: int = 0              # semisync: arrivals per aggregation
    #                                   (0 -> all in-flight participants)
    staleness_power: float = 0.5      # arrival weight (1+tau)^-p
    latency_profile: str = "uniform"  # uniform | straggler | lognormal
    compute_s: float = 1.0            # base seconds per local iteration
    bandwidth_bps: float = 1e8        # base link speed, bits/second
    straggler_frac: float = 0.25      # straggler: fraction of slow clients
    straggler_slowdown: float = 10.0  # straggler: slow-client multiplier
    lognormal_sigma: float = 0.75     # lognormal: client-speed spread
    seed: int = 0                     # latency-sampling salt
    # Dispatch groups larger than this run as a lax-driven sequence of
    # fixed-size client chunks through the ONE-launch batched comm step
    # (autotuned per-chunk kernel geometry), instead of one giant
    # launch; 0 disables chunking. Chunking is bitwise-neutral: each
    # chunk computes exactly the per-client op sequence.
    dispatch_chunk: int = 0           # 0 -> unchunked


#: Robust server-side aggregators (repro.robust). "mean" is today's
#: weighted-mean path, byte-for-byte; the others are pluggable
#: replacements for the combination step over the (K, rows, cols)
#: arrival stack (see docs/robustness.md).
AGGREGATORS = ("mean", "trimmed_mean", "coordinate_median", "norm_clip")

#: Byzantine wire attacks of the fault-injection layer (repro.robust).
#: Each transforms a malicious client's packed uplink buffer after
#: encoding, preserving wire geometry and headers.
ATTACKS = ("none", "sign_flip", "scale", "random_wire")


@dataclass(frozen=True)
class RobustConfig:
    """Adversarial-fleet knobs (repro.robust).

    Three orthogonal groups:

    * **aggregation** — ``aggregator`` picks the server-side combiner
      for client contributions (``AGGREGATORS``). ``trimmed_mean``
      drops the ``trim_fraction`` per-coordinate extremes on each side
      before the weighted mean; ``coordinate_median`` is the maximal
      trim (mid-K survivors); ``norm_clip`` rescales each arrival to
      L2 norm at most ``clip_norm`` before the weighted mean.
    * **byzantine faults** — ``attack`` applied to the packed wire
      buffer of the ``attack_fraction`` lowest-indexed malicious
      clients (deterministic per ``seed``), plus label-noise clients.
    * **fleet churn** — dropout/rejoin events on the virtual clock:
      each dispatch drops with ``dropout_prob`` and rejoins (delivers
      late) after ``rejoin_delay_s`` virtual seconds.

    The default is degenerate by construction: ``aggregator="mean"``
    with no adversaries routes through today's weighted-mean path
    untouched (bitwise), as do ``trimmed_mean`` at trim 0 and
    ``norm_clip`` at clip 0 (see docs/robustness.md).
    """
    aggregator: str = "mean"          # mean | trimmed_mean | coordinate_median | norm_clip
    trim_fraction: float = 0.0        # per-side per-coordinate trim (trimmed_mean)
    clip_norm: float = 0.0            # max L2 norm per arrival (norm_clip; 0 = off)
    # ---- byzantine fault injection ------------------------------------
    attack: str = "none"              # none | sign_flip | scale | random_wire
    attack_fraction: float = 0.0      # fraction of clients byzantine
    attack_scale: float = 10.0        # multiplier for the "scale" attack
    label_noise_fraction: float = 0.0 # fraction of clients with noisy labels
    label_noise_rate: float = 0.5     # P(label resampled) for noisy clients
    # ---- dropout / rejoin on the virtual clock ------------------------
    dropout_prob: float = 0.0         # per-dispatch client dropout probability
    rejoin_delay_s: float = 0.0       # extra virtual seconds before a dropped
    #                                   client's update is delivered
    seed: int = 0                     # fault-injection salt

    @property
    def adversarial(self) -> bool:
        """Any fault injection active (attacks, label noise or churn)."""
        return ((self.attack != "none" and self.attack_fraction > 0.0)
                or self.label_noise_fraction > 0.0
                or self.dropout_prob > 0.0)


@dataclass(frozen=True)
class ObsConfig:
    """Structured telemetry (repro.obs).

    ``probes=True`` adds device-side Sophia health metrics — clip
    fraction of the Eq. 11 step, m/h EMA norms, h-EMA staleness and
    the cumulative GNB refresh count — to the round metrics, computed
    INSIDE the jitted round with no extra host syncs (requires
    ``optimizer="fed_sophia"`` with ``persistent_client_state``; the
    probed round is bitwise identical in state to the unprobed one).
    Sinks, the record schema and the run manifest live in `repro.obs`;
    see docs/observability.md for the metric catalogue.
    """
    probes: bool = False              # device-side Sophia health probes
    #                                   in the round metrics dict
    trace: bool = False               # per-dispatch trace contexts on
    #                                   the virtual clock (repro.obs.trace)
    flush_every: int = 10             # rounds between metric-buffer
    #                                   flushes (host syncs) in obs runs
    ring_capacity: int = 1024         # in-memory ring sink capacity


@dataclass(frozen=True)
class FedConfig:
    """Federated runtime configuration (Alg. 1 hyper-parameters)."""
    num_clients: int = 32
    local_iters: int = 10             # J
    optimizer: str = "fed_sophia"     # fed_sophia | fedavg | done | fedadam | fedyogi
    strategy: str = "parallel"        # parallel (vmap) | sequential (scan)
    lr: float = 3e-3                  # eta
    beta1: float = 0.9
    beta2: float = 0.95
    rho: float = 0.04                 # clip threshold
    eps: float = 1e-12
    weight_decay: float = 1e-4        # lambda
    tau: int = 10                     # hessian refresh period
    hessian_every_unit: str = "step"  # step | round (paper-literal)
    # Persistent per-client (m, h) across rounds (Alg. 1 line 2). False =
    # stateless local optimizer (re-init each round): the memory-feasible
    # variant for >=14B archs where C x |theta| x 2 states cannot fit HBM
    # (DESIGN.md section 4); tau then counts within-round steps.
    persistent_client_state: bool = True
    # server-side optimizer params (FedAdam/FedYogi)
    server_lr: float = 0.1
    server_beta1: float = 0.9
    server_beta2: float = 0.99
    server_eps: float = 1e-3
    # DONE baseline
    done_richardson_iters: int = 20
    done_damping: float = 10.0
    # gradient accumulation: split each local batch into N micro-batches
    # and average the grads (mathematically exact; bounds activation
    # memory — the §Perf HBM-fit lever for large per-client batches)
    grad_microbatches: int = 1
    # schedule: const | cosine | wsd
    schedule: str = "const"
    warmup_rounds: int = 0
    total_rounds: int = 100
    decay_frac: float = 0.1           # WSD decay tail fraction
    use_pallas: bool = False          # fused Sophia kernel (interpret on CPU)
    # client<->server communication model (compression, participation,
    # bytes-on-the-wire accounting) — see repro.comm
    comm: CommConfig = field(default_factory=CommConfig)
    # virtual-time round scheduling (latency model, async/semisync
    # disciplines, staleness weighting) — consumed by repro.sched, not
    # by the engine itself; the default is today's synchronous rounds
    sched: SchedConfig = field(default_factory=SchedConfig)
    # structured telemetry (record schema, sinks, Sophia health probes)
    # — see repro.obs and docs/observability.md; the default is fully
    # off (no probe ops in the traced round)
    obs: ObsConfig = field(default_factory=ObsConfig)
    # adversarial fleet: robust aggregation, byzantine fault injection
    # and client churn — see repro.robust and docs/robustness.md; the
    # default is degenerate (today's weighted-mean path, bitwise)
    robust: RobustConfig = field(default_factory=RobustConfig)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    fed: FedConfig = field(default_factory=FedConfig)
    seed: int = 0
