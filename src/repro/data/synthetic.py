"""Offline synthetic datasets.

The container has no MNIST/FMNIST; we generate seeded class-conditional
image data with the same shape/cardinality (28x28x1, 10 classes) plus a
non-IID Dirichlet partitioner (the paper's setting: 32 devices, non-IID).
A synthetic token stream feeds the LM-family architectures.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

IMAGE_SIZE = 28
NUM_CLASSES = 10


def make_image_data(key, n: int, dataset: str = "mnist",
                    noise: float = 0.35) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Class-conditional smooth prototypes + Gaussian noise.

    'fmnist' uses a different seed-space and higher intra-class variation
    (it is the harder dataset, as in the paper).
    """
    salt = 0 if dataset == "mnist" else 1
    key = jax.random.fold_in(key, salt)
    kp, ky, kn, ka = jax.random.split(key, 4)
    # smooth prototypes: random low-res patterns upsampled
    low = jax.random.normal(kp, (NUM_CLASSES, 7, 7, 1))
    protos = jax.image.resize(low, (NUM_CLASSES, IMAGE_SIZE, IMAGE_SIZE, 1),
                              "cubic")
    protos = protos / (jnp.std(protos, axis=(1, 2, 3), keepdims=True) + 1e-6)
    y = jax.random.randint(ky, (n,), 0, NUM_CLASSES)
    amp = 1.0 + (0.35 if dataset == "fmnist" else 0.15) * \
        jax.random.normal(ka, (n, 1, 1, 1))
    x = amp * protos[y] + noise * jax.random.normal(
        kn, (n, IMAGE_SIZE, IMAGE_SIZE, 1))
    return x.astype(jnp.float32), y


def dirichlet_partition(key, labels, num_clients: int,
                        alpha: float = 0.5) -> np.ndarray:
    """Non-IID split: per-client class mixture ~ Dirichlet(alpha).

    Returns an (C, n_per_client) int index matrix (equalized with
    replacement so it stacks/jits cleanly).
    """
    labels = np.asarray(labels)
    n = labels.shape[0]
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    by_class = [np.where(labels == c)[0] for c in range(NUM_CLASSES)]
    n_per = n // num_clients
    out = np.zeros((num_clients, n_per), np.int32)
    for i in range(num_clients):
        mix = rng.dirichlet(alpha * np.ones(NUM_CLASSES))
        counts = rng.multinomial(n_per, mix)
        idx = np.concatenate([
            rng.choice(by_class[c], size=k, replace=len(by_class[c]) < k)
            for c, k in enumerate(counts) if k > 0])
        rng.shuffle(idx)
        out[i] = idx[:n_per]
    return out


def train_test_split(part: np.ndarray, test_frac: float = 0.25):
    """Per-client 75/25 split (paper §V-A)."""
    n_test = int(part.shape[1] * test_frac)
    return part[:, n_test:], part[:, :n_test]


def client_batches(key, x, y, part: np.ndarray, batch_size: int):
    """Sample one round of per-client minibatches -> leaves (C, b, ...)."""
    C, n_per = part.shape
    b = min(batch_size, n_per)
    cols = jax.random.randint(key, (C, b), 0, n_per)
    idx = jnp.take_along_axis(jnp.asarray(part), cols, axis=1)   # (C,b)
    return {"x": x[idx], "y": y[idx]}


# --------------------------------------------------------------------------
# synthetic token streams for the LM-family architectures
# --------------------------------------------------------------------------

def make_token_batch(key, num_clients: int, batch: int, seq_len: int,
                     vocab_size: int, num_pos_channels: int = 0):
    """Markov-ish token stream: y_t depends on y_{t-1} through a seeded
    permutation plus noise — learnable structure for the LM loss."""
    kperm, kinit, knoise, kmask = jax.random.split(key, 4)
    perm = jax.random.permutation(kperm, vocab_size)
    t0 = jax.random.randint(kinit, (num_clients, batch, 1), 0, vocab_size)

    def step(tok, k):
        nxt = perm[tok]
        flip = jax.random.bernoulli(k, 0.15, tok.shape)
        rnd = jax.random.randint(k, tok.shape, 0, vocab_size)
        return jnp.where(flip, rnd, nxt)

    keys = jax.random.split(knoise, seq_len)
    toks = [t0[..., 0]]
    for i in range(1, seq_len):
        toks.append(step(toks[-1], keys[i]))
    tokens = jnp.stack(toks, axis=-1)                  # (C,B,S)
    labels = jnp.concatenate([tokens[..., 1:], tokens[..., :1]], axis=-1)
    out = {"tokens": tokens, "labels": labels}
    return out
