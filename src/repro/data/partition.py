"""Non-IID client partitioners (repro.data.partition).

Three composable skews over a labelled sample pool, all host-side
numpy and **deterministic per integer seed** (independent
`numpy.random.default_rng` streams — no jax keys, so a partition can
be recomputed from the config alone):

* `dirichlet_label_partition` — label skew: each class's samples are
  apportioned across clients by proportions drawn from
  ``Dirichlet(alpha * 1_C)``.  ``alpha`` is the *concentration knob*:
  large alpha approaches the IID uniform split, small alpha
  concentrates each class on few clients (alpha=0.1 is the standard
  pathological setting).  Every client is guaranteed at least
  ``min_per_client`` samples (pinned, together with determinism and
  the alpha-monotone concentration statistic, by tests/test_data.py).
* `quantity_skew_sizes` — per-client dataset-size skew: client shares
  of the pool drawn from ``Dirichlet(alpha * 1_C)``, apportioned by
  largest remainder, minimum one sample each.
* `feature_shift` — per-client input-distribution shift: client c
  sees ``exp(severity * g_c) * x + severity * b_c`` with per-client
  standard-normal ``g_c, b_c`` (severity 0 is the identity).

`equalize` resamples ragged per-client index lists to the engine's
fixed ``(C, n_per)`` matrix (with replacement only when a client owns
fewer than ``n_per`` uniques), so skewed partitions stack/jit exactly
like the IID ones from `repro.data.synthetic`.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def _apportion(rng, total: int, shares: np.ndarray) -> np.ndarray:
    """Largest-remainder apportionment of `total` items by `shares`
    (a probability vector): exact sum, deterministic tie order."""
    raw = shares * total
    counts = np.floor(raw).astype(np.int64)
    rem = total - int(counts.sum())
    if rem > 0:
        order = np.argsort(-(raw - counts), kind="stable")
        counts[order[:rem]] += 1
    return counts


def _enforce_min(parts: List[np.ndarray],
                 min_per_client: int) -> List[np.ndarray]:
    """Move samples from the largest clients until every client owns
    at least `min_per_client` (deterministic: always steal the tail
    of the currently-largest client)."""
    parts = [np.asarray(p, np.int64).copy() for p in parts]
    for i in range(len(parts)):
        while parts[i].size < min_per_client:
            donor = int(np.argmax([p.size for p in parts]))
            if parts[donor].size <= min_per_client:
                raise ValueError(
                    f"cannot give every client {min_per_client} "
                    f"samples: pool too small")
            parts[i] = np.append(parts[i], parts[donor][-1])
            parts[donor] = parts[donor][:-1]
    return parts


def dirichlet_label_partition(labels, num_clients: int, alpha: float,
                              seed: int, min_per_client: int = 1
                              ) -> List[np.ndarray]:
    """Label-skewed split of a labelled pool.

    For each class, the class's (shuffled) samples are divided among
    the ``num_clients`` clients by proportions drawn from
    ``Dirichlet(alpha * 1_C)``.  Returns a list of C sorted int64
    index arrays (ragged; see `equalize`).  Deterministic per
    ``seed``; every client keeps at least ``min_per_client`` samples.
    """
    if alpha <= 0.0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    labels = np.asarray(labels)
    num_classes = int(labels.max()) + 1
    rng = np.random.default_rng([int(seed), 17])
    parts: List[List[np.ndarray]] = [[] for _ in range(num_clients)]
    for c in range(num_classes):
        idx = np.where(labels == c)[0]
        if idx.size == 0:
            continue
        idx = rng.permutation(idx)
        shares = rng.dirichlet(alpha * np.ones(num_clients))
        counts = _apportion(rng, idx.size, shares)
        for i, chunk in enumerate(np.split(idx, np.cumsum(counts)[:-1])):
            parts[i].append(chunk)
    merged = [np.sort(np.concatenate(p)) if p else
              np.zeros((0,), np.int64) for p in parts]
    return _enforce_min(merged, min_per_client)


def quantity_skew_sizes(n: int, num_clients: int, alpha: float,
                        seed: int, min_per_client: int = 1
                        ) -> np.ndarray:
    """(C,) per-client dataset sizes summing to ``n``, shares drawn
    from ``Dirichlet(alpha * 1_C)``, each at least ``min_per_client``.
    Deterministic per ``seed``."""
    if alpha <= 0.0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    if n < num_clients * min_per_client:
        raise ValueError(
            f"n={n} < num_clients*min_per_client="
            f"{num_clients * min_per_client}")
    rng = np.random.default_rng([int(seed), 18])
    sizes = _apportion(rng, n, rng.dirichlet(alpha * np.ones(num_clients)))
    # deterministic rebalance up to the minimum
    while (sizes < min_per_client).any():
        need = int(np.argmin(sizes))
        donor = int(np.argmax(sizes))
        sizes[need] += 1
        sizes[donor] -= 1
    return sizes


def subsample(parts: Sequence[np.ndarray], sizes: np.ndarray,
              seed: int) -> List[np.ndarray]:
    """Apply quantity skew to a partition: keep a ``sizes[i]``-element
    deterministic random subset of each client's indices (capped at
    what the client owns)."""
    rng = np.random.default_rng([int(seed), 20])
    out = []
    for p, s in zip(parts, sizes):
        p = np.asarray(p, np.int64)
        k = min(int(s), p.size)
        out.append(np.sort(rng.choice(p, size=k, replace=False)))
    return out


def equalize(parts: Sequence[np.ndarray], n_per: int,
             seed: int) -> np.ndarray:
    """Resample ragged per-client index lists to the engine's fixed
    (C, n_per) int32 matrix — without replacement when a client owns
    >= n_per uniques, with replacement otherwise (oversampling small
    clients preserves their skewed effective distribution)."""
    rng = np.random.default_rng([int(seed), 21])
    out = np.zeros((len(parts), n_per), np.int32)
    for i, p in enumerate(parts):
        p = np.asarray(p, np.int64)
        if p.size == 0:
            raise ValueError(f"client {i} owns no samples")
        out[i] = rng.choice(p, size=n_per, replace=p.size < n_per)
    return out


def feature_shift(x_clients, severity: float, seed: int):
    """Per-client feature shift of a stacked (C, ...) input array:
    client c sees ``exp(severity * g_c) * x + severity * b_c`` with
    per-client standard-normal gain/offset draws.  severity=0.0 is
    the identity.  Deterministic per ``seed``; returns a new float32
    numpy array."""
    x = np.asarray(x_clients, np.float32)
    if severity == 0.0:
        return x.copy()
    C = x.shape[0]
    rng = np.random.default_rng([int(seed), 19])
    tail = (1,) * (x.ndim - 1)
    gain = np.exp(severity * rng.standard_normal(C)).reshape((C,) + tail)
    bias = (severity * rng.standard_normal(C)).reshape((C,) + tail)
    return (gain * x + bias).astype(np.float32)


def label_marginals(labels, parts: Sequence[np.ndarray],
                    num_classes: int) -> np.ndarray:
    """(C, num_classes) per-client label distribution of a partition."""
    labels = np.asarray(labels)
    out = np.zeros((len(parts), num_classes), np.float64)
    for i, p in enumerate(parts):
        counts = np.bincount(labels[np.asarray(p, np.int64)],
                             minlength=num_classes)
        out[i] = counts / max(1, counts.sum())
    return out


def label_concentration(marginals: np.ndarray) -> float:
    """Scalar skew statistic: the mean (over clients) max class share.
    1/num_classes for perfectly IID clients, -> 1.0 as each client
    collapses onto a single class — monotone in 1/alpha in
    expectation (pinned statistically by tests/test_data.py)."""
    return float(np.mean(marginals.max(axis=1)))
