"""Learning-rate schedules over communication rounds.

WSD (warmup-stable-decay) is included because the minicpm-2b assigned
architecture cites it as its training schedule [arXiv:2404.06395].
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import FedConfig


def lr_at_round(fed: FedConfig, round_idx):
    """Traced-friendly lr(round). round_idx may be a tracer."""
    r = jnp.asarray(round_idx, jnp.float32)
    total = max(fed.total_rounds, 1)
    warm = fed.warmup_rounds
    base = fed.lr
    if fed.schedule == "const":
        lr = jnp.full((), base)
    elif fed.schedule == "cosine":
        t = jnp.clip((r - warm) / max(total - warm, 1), 0.0, 1.0)
        lr = base * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    elif fed.schedule == "wsd":
        decay_start = total * (1.0 - fed.decay_frac)
        t = jnp.clip((r - decay_start) / max(total * fed.decay_frac, 1), 0.0, 1.0)
        lr = base * (1.0 - t * (1.0 - 0.1))      # linear decay to 10%
    else:
        raise ValueError(fed.schedule)
    if warm > 0:
        lr = lr * jnp.clip((r + 1.0) / warm, 0.0, 1.0)
    return lr
