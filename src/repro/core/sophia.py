"""The Sophia update (Liu et al. 2023) as used by Fed-Sophia (Alg. 1).

Two twins with identical per-coordinate semantics:

* the pytree form (`sophia_step` and friends) — the reference the
  paper-facing tests pin, still selectable onto the fused Pallas
  kernel via ``use_pallas``;
* the flat form (`sophia_step_flat`) — one packed (rows, cols) fp32
  buffer per state stream, consumed by the flat-resident round engine
  (`repro.core.fed`), where the kernel path needs **zero** layout
  conversion because the engine already holds theta/m/h in the wire
  layout (docs/architecture.md "Memory layout").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SophiaState(NamedTuple):
    m: object   # EMA of gradients       (Eq. 9)
    h: object   # EMA of Hessian diag    (Eq. 10)


def init_state(params) -> SophiaState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return SophiaState(m=zeros, h=jax.tree.map(jnp.zeros_like, params))


def update_m(m, grads, beta1: float):
    """Eq. 9: m <- b1 m + (1-b1) g."""
    return jax.tree.map(lambda mm, g: beta1 * mm + (1.0 - beta1) * g, m, grads)


def update_h(h, h_hat, beta2: float):
    """Eq. 10: h <- b2 h + (1-b2) h_hat."""
    return jax.tree.map(lambda hh, e: beta2 * hh + (1.0 - beta2) * e, h, h_hat)


def clip(z, rho: float):
    """Eq. 11: elementwise clip to [-rho, rho]."""
    return jnp.clip(z, -rho, rho)


def apply_update(params, m, h, *, lr: float, rho: float, eps: float,
                 weight_decay: float):
    """Alg. 1 lines 15-16: decoupled weight decay then clipped
    pre-conditioned step  theta <- theta - lr*clip(m / max(h, eps), rho)."""
    def leaf(theta, mm, hh):
        dtype = theta.dtype
        theta = theta - lr * weight_decay * theta
        step = clip(mm / jnp.maximum(hh, eps), rho)
        return (theta - lr * step).astype(dtype)
    return jax.tree.map(leaf, params, m, h)


def sophia_step(params, grads, state: SophiaState, h_hat, do_h_update,
                *, lr, beta1, beta2, rho, eps, weight_decay,
                use_pallas: bool = False):
    """One full local iteration of Alg. 1 (lines 7-16).

    h_hat: GNB estimate pytree (only consumed when do_h_update).
    do_h_update: traced bool — h-EMA applied under lax.cond-style select.
    """
    if use_pallas:
        # single fused Pallas pass: m-EMA, gated h-EMA, decay, clip, update
        from repro.kernels.ops import sophia_fused_step
        params, m, h = sophia_fused_step(
            params, state.m, state.h, grads, h_hat, do_h_update,
            lr=lr, beta1=beta1, beta2=beta2, rho=rho, eps=eps,
            weight_decay=weight_decay)
        return params, SophiaState(m=m, h=h)
    m = update_m(state.m, grads, beta1)
    h_new = update_h(state.h, h_hat, beta2)
    h = jax.tree.map(
        lambda new, old: jnp.where(do_h_update, new, old), h_new, state.h)
    params = apply_update(params, m, h, lr=lr, rho=rho, eps=eps,
                          weight_decay=weight_decay)
    return params, SophiaState(m=m, h=h)


def sophia_step_flat(theta, m, h, grads, h_hat, do_h_update, *, lr, beta1,
                     beta2, rho, eps, weight_decay,
                     use_pallas: bool = False):
    """`sophia_step` over packed (rows, cols) wire buffers.

    Bit-identical per coordinate to the pytree form for fp32 buffers
    (the ops are all elementwise; the zero pad tail is a fixed point,
    so packed state stays valid wire buffers across iterations).
    With ``use_pallas`` the buffers feed the fused kernel directly —
    no pack/unpack.  Follows the kernel layer's dtype contract: bf16
    resident buffers (`CommConfig.state_dtype`) are upcast to fp32
    for the arithmetic and the results stored back in each input's
    dtype (no-op casts for fp32).  Returns ``(theta, m, h)``.

    Also accepts packed (clients, rows, cols) stacks: the pure path
    is elementwise and shape-agnostic, and the kernel path dispatches
    to the client-batched launch (`sophia_update_batched`) — ONE
    kernel call for the whole cohort, bitwise equal to per-client
    calls.
    """
    if use_pallas:
        from repro.kernels import INTERPRET
        from repro.kernels.sophia_update import (sophia_update_batched,
                                                 sophia_update_flat)
        fn = sophia_update_batched if theta.ndim == 3 else sophia_update_flat
        return fn(
            theta, m, h, grads, h_hat, do_h_update, lr, beta1=beta1,
            beta2=beta2, rho=rho, eps=eps, weight_decay=weight_decay,
            interpret=INTERPRET)
    out_dt = (theta.dtype, m.dtype, h.dtype)
    theta, m, h, grads, h_hat = (x.astype(jnp.float32)
                                 for x in (theta, m, h, grads, h_hat))
    m = beta1 * m + (1.0 - beta1) * grads                          # Eq. 9
    h = jnp.where(do_h_update,
                  beta2 * h + (1.0 - beta2) * h_hat, h)            # Eq. 10
    theta = theta - lr * weight_decay * theta                      # line 15
    step = clip(m / jnp.maximum(h, eps), rho)                      # Eq. 11
    return ((theta - lr * step).astype(out_dt[0]),                 # line 16
            m.astype(out_dt[1]), h.astype(out_dt[2]))
