"""Federated runtime: one jitted call = one communication round (Alg. 1).

Two execution strategies (DESIGN.md §4):
  * parallel   — vmap over a leading client axis; client axis is sharded
                 along the mesh 'data' (and 'pod') axes, so the final
                 aggregation mean lowers to the cross-client all-reduce
                 that realises Eq. 4.
  * sequential — lax.scan over clients; each client trains with the whole
                 mesh (FSDP); memory O(1) in the number of clients.

Optimizers: fed_sophia (the paper), fedavg, done, fedadam, fedyogi.

Memory layout (docs/architecture.md "Memory layout"): the engine is
**flat-resident** — the packed (rows, cols) fp32 wire buffer of
`repro.comm.flat` is the canonical in-round representation of every
piece of client-visible state: the round-start model, each client's
evolving theta, the Sophia m/h EMAs (stored across rounds as
(C, rows, cols) arrays), GNB estimates, uplink EF residuals and
downlink replicas.  Pytrees are materialized only at the loss/grad
boundary — one `unpack` view feeds `value_and_grad`, one `pack` lays
the returned grads back — so the fused Pallas kernels and the wire
compressors consume state that is *already* in their layout, the
uplink delta is a flat subtraction, and the hessian stream reads
``opt.h`` without conversion.  Leaf flattening order is frozen
(`flat.FlatSpec`), which makes the flat round bit-identical to the
historical pytree engine for fp32 models (tests/test_flat_engine.py
pins this per config).

Device residency (docs/architecture.md "Memory layout: the life of a
round"): the engine goes one step further than in-round flatness —

* **Packed params between rounds.** `pack_state` re-lays
  ``state["params"]`` (and the FedOpt server m/v) as wire buffers, and
  `round` consumes/produces them without the per-round pack/unpack
  bracket; the pytree then exists only at the init / eval / checkpoint
  boundaries (`unpack_params` / `unpack_state` are the inverse shims).
* **Buffer donation.** `round_fn(donate=True)` jits the round with the
  state argument donated, so on donation-capable backends theta, the
  (C, rows, cols) Sophia m/h stacks, EF residuals and downlink
  replicas update IN PLACE — zero per-round device copies of resident
  client state.  Contract: the caller must not touch the state it
  passed in after the call (XLA invalidates those buffers); rebind the
  returned state, as ``state, metrics = round_fn(state, ...)`` does.
* **bf16 resident state.** ``CommConfig.state_dtype="bfloat16"``
  stores all resident wire-layout state in bf16 (half the HBM);
  gathered rows feed the kernels *in their storage dtype* — the
  kernels upcast loads to fp32 in-VMEM (`repro.kernels` dtype
  contract), jnp promotion handles the mixed-dtype flat arithmetic
  exactly, and rows downcast on the scatter back (`_store`).  No
  bulk gather-side upcast ever materializes an fp32 copy of resident
  state.  Wire bytes are unaffected; fp32 configs see only no-op
  casts and stay bit-identical (tests/test_residency.py).

* **Client-batched kernels.** The parallel strategy steps the whole
  cohort through ONE client-batched pipeline (`comm_client_step_
  batched`): downlink broadcast, the local Sophia scan, uplink
  encode and the hessian round-trip each run as a single Pallas
  launch over the packed (C, rows, cols) stacks instead of C vmapped
  (rows, cols) launches — bitwise equal to the vmapped per-client
  path (tests/test_residency.py pins it).

Communication model (repro.comm): with the default CommConfig (lossless
identity uplink/downlink, hessian stream off, full participation) the
round aggregates client params directly — bit-identical to the original
engine.  Any compression, partial participation, or extra stream routes
through the multi-stream delta-space pipeline:

    [downlink]  broadcast delta theta - theta_i^rx (+ server EF)
                -> encode/decode -> client model replica updated
    local-train from theta_i^rx
    [uplink]    delta = theta_i - theta_i^rx (+ client EF residual)
                -> encode/decode over the packed wire buffer
    [hessian]   (optional) compressed Sophia h-EMA uplink
    server: participation-weighted mean of reconstructions; applies the
    aggregated model delta (or FedOpt on it) and broadcasts ONE common
    averaged-curvature payload back to the participants.

Round metrics always include exact per-stream byte counts.

Beyond the synchronous round, `comm_client_step` is the reusable
per-participant core (broadcast -> local train -> uplink encode): the
virtual-time scheduler (`repro.sched`) drives it one dispatch at a
time for asynchronous / semi-synchronous disciplines, with
`comm_runtime` supplying the per-stream (spec, compressor) handles —
memoized on the params' avals, so re-traces and scheduler dispatches
reuse one construction — and `wire_headers` fingerprinting the wire
layouts (including the flat client-state layout) for checkpoint
restore.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import accounting, downlink as cdown, flat as cflat
from repro.comm.compressors import (make_compressor, make_stream_compressor,
                                    participation_indices,
                                    wants_error_feedback)
from repro.configs.base import AGGREGATORS, ATTACKS, FedConfig
from repro.core import sophia
from repro.core.gnb import gnb_estimate
from repro.kernels import INTERPRET as _INTERPRET
from repro.obs import probes as obs_probes
from repro.core.schedules import lr_at_round
from repro.robust import aggregators as robust_agg
from repro.robust import attacks as robust_attacks
from repro.utils.tree import (tree_count_params, tree_sq_norm,
                              tree_zeros_like)


#: rng salt of the per-round participation sample (shared by
#: `FedEngine._round_comm` and `FedEngine.round_participants`)
PARTICIPATION_SALT = 0x9A70


class CommRuntime(NamedTuple):
    """Trace-time comm-path handles: one (spec, compressor) per active
    stream.  ``spec`` (the uplink layout) doubles as the canonical
    geometry of all flat-resident engine state.  Per-stream packing
    geometry (``CommConfig.downlink_quant_block`` /
    ``hessian_quant_block``) means the streams may disagree on
    (rows, cols); they always share the flattened ``total`` coordinate
    order, so `repro.comm.flat.repack` moves buffers between
    geometries (a no-op in the traced graph when they agree)."""
    spec: Any                      # uplink layout == engine state layout
    comp: Any                      # uplink compressor
    spec_dn: Any = None
    comp_dn: Any = None
    spec_h: Any = None
    comp_h: Any = None

    @property
    def dn_on(self) -> bool:
        return self.comp_dn is not None

    @property
    def h_on(self) -> bool:
        return self.comp_h is not None


class FedEngine:
    def __init__(self, task, fed: FedConfig, gather_shardings=None):
        self.task = task
        self.fed = fed
        if fed.comm.hessian_enabled and not (
                fed.optimizer == "fed_sophia"
                and fed.persistent_client_state):
            raise ValueError(
                "the hessian comm stream aggregates the Sophia h-EMA: it "
                "requires optimizer='fed_sophia' with "
                "persistent_client_state=True")
        if fed.obs.probes and not (
                fed.optimizer == "fed_sophia"
                and fed.persistent_client_state):
            raise ValueError(
                "ObsConfig.probes reads the persistent Sophia m/h EMAs: "
                "it requires optimizer='fed_sophia' with "
                "persistent_client_state=True")
        rb = fed.robust
        if rb.aggregator not in AGGREGATORS:
            raise ValueError(
                f"unknown aggregator {rb.aggregator!r} (want one of "
                f"{AGGREGATORS})")
        if rb.attack not in ATTACKS:
            raise ValueError(
                f"unknown attack {rb.attack!r} (want one of {ATTACKS})")
        if not 0.0 <= rb.trim_fraction < 0.5:
            raise ValueError(
                f"trim_fraction={rb.trim_fraction} must be in [0, 0.5) "
                "(trimming both sides must leave a survivor)")
        for name in ("attack_fraction", "label_noise_fraction",
                     "label_noise_rate", "dropout_prob"):
            v = getattr(rb, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} must be in [0, 1]")
        # FSDP (sequential strategy): params are STORED sharded over the
        # data axes; each use must see them model-only-sharded, otherwise
        # GSPMD resolves the data-axis contraction by replicating the
        # batch-sharded activations instead (16x activation traffic).
        # gather_shardings = model-only NamedSharding pytree; constraining
        # params to it at each local step lowers to the per-step weight
        # all-gather that defines FSDP/ZeRO-3.
        self.gather_shardings = gather_shardings
        # comm_runtime memoization: specs/compressors are pure static
        # metadata, keyed on the params' avals (the engine's CommConfig
        # is immutable, so it needs no key component)
        self._rt_cache: Dict[Any, CommRuntime] = {}
        # the runtime of the packed-resident state (set by init /
        # pack_state / restore shims): packed buffers carry no treedef,
        # so rounds over packed state read the layout from here
        self._packed_rt: CommRuntime | None = None

    # ------------------------------------------------- residency helpers
    @property
    def state_dtype(self):
        """Storage dtype of resident wire-layout state
        (`CommConfig.state_dtype`); in-round compute is always fp32."""
        return cflat.as_dtype(self.fed.comm.state_dtype)

    @property
    def moment_dtype(self):
        """Storage dtype of the (C, rows, cols) Sophia m stack
        (`CommConfig.moment_dtype`, "" -> `state_dtype`)."""
        return cflat.as_dtype(self.fed.comm.moment_dtype
                              or self.fed.comm.state_dtype)

    @property
    def hessian_dtype(self):
        """Storage dtype of the (C, rows, cols) Sophia h stack
        (`CommConfig.hessian_dtype`, "" -> `state_dtype`)."""
        return cflat.as_dtype(self.fed.comm.hessian_dtype
                              or self.fed.comm.state_dtype)

    @staticmethod
    def params_packed(params) -> bool:
        """Whether ``state["params"]`` is a packed (rows, cols) wire
        buffer (packed-resident mode, `pack_state`) rather than a
        parameter pytree.  Model pytrees are containers, never a bare
        rank-2 array, so the array rank is the discriminator."""
        return getattr(params, "ndim", None) == 2

    def _store(self, tree):
        """Scatter-side downcast: fp32 compute values -> the resident
        storage dtype.  No-op for fp32 state."""
        if tree is None:
            return None
        dt = self.state_dtype
        return jax.tree.map(lambda x: x.astype(dt), tree)

    def _store_opt(self, opt):
        """Scatter-side downcast of Sophia m/h to their per-buffer
        resident dtypes (`CommConfig.moment_dtype`/`hessian_dtype`,
        falling back to `state_dtype`).  No-op for fp32 state."""
        if opt is None:
            return None
        return sophia.SophiaState(m=opt.m.astype(self.moment_dtype),
                                  h=opt.h.astype(self.hessian_dtype))

    def _gathered(self, params):
        if self.gather_shardings is None:
            return params
        return jax.tree.map(jax.lax.with_sharding_constraint, params,
                            self.gather_shardings)

    def _stateful(self) -> bool:
        """Persistent per-client Sophia state lives in the engine state
        dict (as (C, rows, cols) wire-layout buffers)."""
        return (self.fed.optimizer == "fed_sophia"
                and self.fed.persistent_client_state)

    def _value_and_grad(self, loss_fn, params, batch, rng=None):
        """value_and_grad with optional exact micro-batch accumulation."""
        n = self.fed.grad_microbatches
        if n <= 1:
            return jax.value_and_grad(loss_fn)(params, batch, rng)
        mb = jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

        def body(acc, xs):
            i, b = xs
            r = jax.random.fold_in(rng, i) if rng is not None else None
            l, g = jax.value_and_grad(loss_fn)(params, b, r)
            acc = (acc[0] + l / n,
                   jax.tree.map(lambda a, gg: a + gg / n, acc[1], g))
            return acc, None

        init = (jnp.zeros((), jnp.float32), tree_zeros_like(params))
        (loss, grads), _ = jax.lax.scan(
            body, init, (jnp.arange(n), mb))
        return loss, grads

    def _flat_value_and_grad(self, theta, batch, spec, rng=None):
        """The loss/grad boundary of the flat-resident engine: ONE
        unpack materializes the pytree view for `value_and_grad`, ONE
        pack lays the grads back into wire layout.  Also returns the
        (gathered) pytree view so callers needing it (GNB refresh)
        reuse the same unpack."""
        pg = self._gathered(cflat.unpack(theta, spec))
        loss, grads = self._value_and_grad(self.task.loss, pg, batch, rng)
        return loss, cflat.pack(grads, spec), pg

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        params = self.task.init(key)
        state: Dict[str, Any] = {"params": params,
                                 "round": jnp.zeros((), jnp.int32)}
        rt = self.comm_runtime(params)
        self._packed_rt = rt
        C = self.fed.num_clients
        comm = self.fed.comm
        dt = self.state_dtype
        if self._stateful():
            # per-client Sophia EMAs, stored directly in wire layout
            # (and in the resident storage dtype) — the local loop and
            # the hessian stream consume them with zero conversion
            state["client_opt"] = sophia.SophiaState(
                m=cflat.zeros(rt.spec, (C,), self.moment_dtype),
                h=cflat.zeros(rt.spec, (C,), self.hessian_dtype))
        if self.fed.optimizer in ("fedadam", "fedyogi"):
            state["server_opt"] = {"m": tree_zeros_like(params),
                                   "v": tree_zeros_like(params)}
        if wants_error_feedback(comm):
            # per-client error-feedback residual, stored in uplink
            # wire layout
            state["comm_ef"] = cflat.zeros(rt.spec, (C,), dt)
        if comm.downlink_enabled:
            # per-client last-received model replicas (+ server-side
            # EF), stored in the downlink stream's own layout
            state.update(cdown.init_state(
                comm, rt.spec_dn,
                cflat.repack(cflat.pack(params, rt.spec, dtype=dt),
                             rt.spec, rt.spec_dn),
                C, dtype=dt))
        return state

    def restore_params(self, state, params) -> Dict[str, Any]:
        """Swap restored params into ``state``, rebuilding the
        wire-layout client state that references the model: downlink
        replicas must re-sync to the restored params (a delta-coded
        broadcast against the old init would be garbage) and EF
        residuals restart at zero."""
        state = {**state, "params": params}
        rt = self.comm_runtime(params)
        self._packed_rt = rt
        comm = self.fed.comm
        if "comm_ef" in state:
            state["comm_ef"] = tree_zeros_like(state["comm_ef"])
        if comm.downlink_enabled:
            state.update(cdown.init_state(
                comm, rt.spec_dn,
                cflat.repack(cflat.pack(params, rt.spec,
                                        dtype=self.state_dtype),
                             rt.spec, rt.spec_dn),
                self.fed.num_clients, dtype=self.state_dtype))
        return state

    # ------------------------------------------- packed-resident boundary
    def pack_state(self, state) -> Dict[str, Any]:
        """Re-lay ``state["params"]`` (and the FedOpt server m/v) as
        wire buffers so the state is device-resident in wire layout
        BETWEEN rounds too: `round` then consumes and returns packed
        buffers with no per-round pack/unpack bracket.  Idempotent.
        The pytree reappears only through `unpack_params` /
        `unpack_state` (eval/checkpoint boundaries)."""
        params = state["params"]
        if self.params_packed(params):
            return state
        rt = self.comm_runtime(params)
        self._packed_rt = rt
        dt = self.state_dtype
        out = {**state, "params": cflat.pack(params, rt.spec, dtype=dt)}
        if "server_opt" in state:
            out["server_opt"] = {
                k: cflat.pack(v, rt.spec, dtype=dt)
                for k, v in state["server_opt"].items()}
        return out

    def unpack_state(self, state) -> Dict[str, Any]:
        """Inverse of `pack_state`: materialize the params (and FedOpt
        server m/v) pytrees.  Idempotent on tree-resident state."""
        params = state["params"]
        if not self.params_packed(params):
            return state
        spec = self._require_packed_rt().spec
        out = {**state, "params": cflat.unpack(params, spec)}
        if "server_opt" in state:
            out["server_opt"] = {
                k: cflat.unpack(v, spec)
                for k, v in state["server_opt"].items()}
        return out

    def unpack_params(self, state):
        """The params pytree view of ``state`` regardless of residency
        — the eval/checkpoint shim of the packed-resident engine."""
        params = state["params"]
        if not self.params_packed(params):
            return params
        return cflat.unpack(params, self._require_packed_rt().spec)

    def _require_packed_rt(self) -> CommRuntime:
        if self._packed_rt is None:
            raise ValueError(
                "packed-resident state reached the engine before its "
                "layout was established — create the state with this "
                "engine's init()+pack_state() (or restore through its "
                "shims) so the packed spec is known")
        return self._packed_rt

    def runtime_for(self, params) -> CommRuntime:
        """`comm_runtime` for either residency: pytree params build
        (memoized) specs; packed params read the layout recorded by
        `pack_state`."""
        if self.params_packed(params):
            return self._require_packed_rt()
        return self.comm_runtime(params)

    def num_params(self, state) -> int:
        """True model coordinate count under either residency (the
        packed buffer's pad tail never counts)."""
        params = state["params"]
        if self.params_packed(params):
            return self._require_packed_rt().spec.total
        return tree_count_params(params)

    def round_fn(self, *, donate: bool = True):
        """The jitted round entry point.

        With ``donate=True`` the state argument is donated to XLA:
        on donation-capable backends every resident buffer — packed
        params, the (C, rows, cols) Sophia m/h stacks, EF residuals,
        downlink replicas — is updated IN PLACE (zero per-round device
        copies of client state).  Donation contract: the caller must
        not reuse the state object it passed in (its buffers are
        invalidated); rebind the return value, as in
        ``state, metrics = round_fn(state, batches, rng)``.
        """
        if donate:
            return jax.jit(self.round, donate_argnums=(0,))
        return jax.jit(self.round)

    # ------------------------------------------------------ comm plumbing
    def uses_direct_path(self) -> bool:
        """Whether `round` takes the direct client-mean path (lossless
        identity, full participation, no extra streams) instead of the
        delta-space comm path."""
        comm = self.fed.comm
        C = self.fed.num_clients
        return (comm.lossless and comm.num_participants(C) == C
                and not comm.multi_stream)

    def round_participants(self, rng) -> jnp.ndarray:
        """The client ids `round(state, batches, rng)` trains — the
        direct path trains everyone; the comm path gathers the
        participation sample.  The single source of truth for
        schedulers/reports that need the cohort outside the jit."""
        C = self.fed.num_clients
        if self.uses_direct_path():
            return jnp.arange(C)
        return participation_indices(
            jax.random.fold_in(rng, PARTICIPATION_SALT
                               + self.fed.comm.seed),
            C, self.fed.comm.num_participants(C))

    def comm_runtime(self, params) -> CommRuntime:
        """The per-stream (spec, compressor) handles — trace-time only
        (specs/compressors hold no arrays), memoized on the params'
        avals so every round trace, scheduler dispatch and init/restore
        shares one construction instead of re-flattening the pytree."""
        key = cflat.aval_key(params)
        rt = self._rt_cache.get(key)
        if rt is not None:
            return rt
        comm = self.fed.comm
        spec = cflat.flat_spec(params, cols=comm.quant_block)
        kw: Dict[str, Any] = {}
        if comm.downlink_enabled:
            s = cflat.flat_spec(
                params, cols=comm.stream("downlink").quant_block)
            kw.update(spec_dn=s,
                      comp_dn=make_stream_compressor(comm, "downlink", s))
        if comm.hessian_enabled:
            s = cflat.flat_spec(
                params, cols=comm.stream("hessian").quant_block)
            kw.update(spec_h=s,
                      comp_h=make_stream_compressor(comm, "hessian", s))
        rt = CommRuntime(spec=spec, comp=make_compressor(comm, spec), **kw)
        self._rt_cache[key] = rt
        return rt

    def wire_headers(self, params) -> Dict[str, Dict[str, Any]]:
        """Versioned wire-layout headers of every active stream — plus
        the ``client_state`` layout fingerprint of the flat-resident
        per-client optimizer state — as plain dicts.  Store them in
        checkpoint manifests; `repro.comm.flat.check_headers` rejects a
        restore whose comm/EF/client state was written under a
        different layout."""
        rt = self.runtime_for(params)
        out = {"uplink": rt.comp.header().to_dict()}
        if rt.dn_on:
            out["downlink"] = rt.comp_dn.header().to_dict()
        if rt.h_on:
            out["hessian"] = rt.comp_h.header().to_dict()
        if self._stateful():
            # the Sophia m/h buffers are stored in wire layout (and in
            # the resident storage dtype): a restore under a different
            # packing geometry or dtype would silently re-interpret
            # the rows
            out["client_state"] = cflat.Header(
                compressor="identity", total=rt.spec.total,
                quant_block=rt.spec.cols,
                state_dtype=self.fed.comm.state_dtype).to_dict()
        return out

    def comm_client_step(self, rt: CommRuntime, theta, theta_dn,
                         round_idx, lr, opt, ef_i, dnm_i, dnef_i, batch,
                         crng):
        """One participant's comm-path step — the reusable core of
        `_round_comm`, also driven one dispatch at a time by the
        virtual-time scheduler (`repro.sched`):

        downlink broadcast (replica update) -> local training from the
        received model -> fused uplink delta encode/decode [-> hessian-
        EMA encode/decode].

        Everything stays in wire layout: ``theta`` is the packed server
        model (canonical ``rt.spec`` geometry; ``theta_dn`` the same
        coordinates in the downlink geometry, None when that stream is
        off), the received replica *is* the local-training start state,
        and the uplink delta is a flat subtraction inside
        `Compressor.encode_delta`.  Gathered resident rows flow in
        UN-upcast (`CommConfig.state_dtype`): the kernels upcast loads
        to fp32 in-VMEM and jnp promotion covers the flat arithmetic;
        callers downcast the returned rows on the scatter back
        (`_store`).  For fp32 state every cast is a no-op.

        Returns ``(xhat, stat, ef_new, opt_new, loss, dnm_new,
        dnef_new, h_hat, h_stat)`` with ``None`` for inactive pieces.
        """
        if rt.dn_on:
            dnm_i, dnef_i = cdown.broadcast(
                rt.comp_dn, jax.random.fold_in(crng, 0xD0),
                theta_dn, dnm_i, dnef_i)
            start = cflat.repack(dnm_i, rt.spec_dn, rt.spec)
        else:
            start = theta
        t_i, opt_i, loss = self._local_update_flat(
            rt.spec, start, opt, batch, crng, round_idx, lr)
        xhat, stat, ef_new = rt.comp.encode_delta(
            jax.random.fold_in(crng, 0xC0), t_i, start, ef_i)
        h_hat = h_stat = None
        if rt.h_on:
            # opt.h is already a wire buffer; only a geometry re-lay
            # (if the hessian stream packs its own quant_block) stands
            # between it and the compressor.  The explicit fp32 upcast
            # keeps the wire semantics (scales, payload dtype) fixed
            # when the resident EMAs are stored bf16 (no-op for fp32).
            h_hat, h_stat = rt.comp_h.roundtrip(
                jax.random.fold_in(crng, 0x4E),
                cflat.repack(opt_i.h, rt.spec,
                             rt.spec_h).astype(jnp.float32))
        return (xhat, stat, ef_new, opt_i, loss,
                dnm_i if rt.dn_on else None, dnef_i, h_hat, h_stat)

    def comm_client_step_batched(self, rt: CommRuntime, theta, theta_dn,
                                 round_idx, lr, opts, efs, dnms, dnefs,
                                 batches, crngs):
        """`comm_client_step` for the whole cohort in one pass — the
        parallel strategy's client step, and the scheduler's batched
        dispatch.

        Every per-client buffer argument carries a leading client axis
        N (None when that piece is off); ``theta`` / ``theta_dn`` stay
        the one shared packed server model; ``crngs``: (N,) per-client
        rng keys.  Each stage — downlink broadcast, the local Sophia
        scan, uplink encode, the hessian round-trip — runs as ONE
        client-batched Pallas launch over the (N, rows, cols) stacks
        (`repro.kernels`) instead of N per-client launches, and is
        bitwise equal to ``jax.vmap(comm_client_step)`` over the same
        rows (tests/test_residency.py pins it).  Returns the same
        9-tuple as `comm_client_step`, stacked along clients.

        Dispatch groups larger than `SchedConfig.dispatch_chunk`
        (when set) run as a lax-driven sequence of fixed-size chunks
        through this same batched path — see
        `_comm_client_step_chunked`; each chunk is bitwise the
        unchunked batched step over its rows.
        """
        chunk = self.fed.sched.dispatch_chunk
        if 0 < chunk < int(crngs.shape[0]):
            return self._comm_client_step_chunked(
                rt, theta, theta_dn, round_idx, lr, opts, efs, dnms,
                dnefs, batches, crngs, chunk)
        if rt.dn_on:
            keys = jax.vmap(
                lambda k: jax.random.fold_in(k, 0xD0))(crngs)
            dnms, dnefs = cdown.broadcast_batched(
                rt.comp_dn, keys, theta_dn, dnms, dnefs)
            starts = jax.vmap(
                lambda b: cflat.repack(b, rt.spec_dn, rt.spec))(dnms)
        else:
            starts = theta
        t, opt, losses = self._local_update_flat_batched(
            rt.spec, starts, opts, batches, crngs, round_idx, lr)
        xhat, stat, ef_new = rt.comp.encode_delta_batched(
            jax.vmap(lambda k: jax.random.fold_in(k, 0xC0))(crngs),
            t, starts, efs)
        h_hat = h_stat = None
        if rt.h_on:
            h_rows = jax.vmap(
                lambda hrow: cflat.repack(hrow, rt.spec, rt.spec_h)
            )(opt.h).astype(jnp.float32)
            h_hat, h_stat = rt.comp_h.roundtrip_batched(
                jax.vmap(lambda k: jax.random.fold_in(k, 0x4E))(crngs),
                h_rows)
        return (xhat, stat, ef_new, opt, losses,
                dnms if rt.dn_on else None, dnefs, h_hat, h_stat)

    def _comm_client_step_chunked(self, rt: CommRuntime, theta, theta_dn,
                                  round_idx, lr, opts, efs, dnms, dnefs,
                                  batches, crngs, chunk: int):
        """Large-group dispatch: run an N-client group as a
        `lax.map`-driven sequence of fixed-size ``chunk`` launches of
        `comm_client_step_batched` (the autotuned per-chunk kernel
        geometry — `kernels.tuning` keys on the chunk's client count),
        plus one direct tail call for the N % chunk remainder.

        Every per-client stack is reshaped (N, ...) -> (G, chunk, ...)
        so the compiled graph holds ONE chunk-sized program body
        regardless of G; the shared ``theta``/``theta_dn`` broadcast
        into the body unchanged.  Per-chunk results are bitwise the
        unchunked batched step over the same rows (each stage is
        elementwise per client row), pinned by
        tests/test_residency.py."""
        n = int(crngs.shape[0])
        g = n // chunk
        per_client = (opts, efs, dnms, dnefs, batches, crngs)
        head = jax.tree.map(
            lambda x: x[:g * chunk].reshape((g, chunk) + x.shape[1:]),
            per_client)
        outs = jax.lax.map(
            lambda c: self.comm_client_step_batched(
                rt, theta, theta_dn, round_idx, lr, *c), head)
        outs = jax.tree.map(
            lambda x: x.reshape((g * chunk,) + x.shape[2:]), outs)
        if n % chunk:
            rest = jax.tree.map(lambda x: x[g * chunk:], per_client)
            tail = self.comm_client_step_batched(
                rt, theta, theta_dn, round_idx, lr, *rest)
            outs = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), outs, tail)
        return outs

    # ------------------------------------------- local client training (flat)
    def _local_sophia_flat(self, spec, theta, m, h, batch, round_idx, rng,
                           lr):
        """Flat-resident Sophia local loop: theta/m/h are (rows, cols)
        wire buffers for the whole scan; the pytree exists only as the
        per-iteration `value_and_grad` view (plus the GNB estimate on
        refresh iterations, packed inside its lax.cond)."""
        fed = self.fed
        task = self.task

        # round mode (Alg. 1 line 9 literal: refresh when k mod tau == 0):
        # the GNB estimate uses the round-start params, so it hoists out of
        # the local-iteration scan — one estimator call per refresh round
        # instead of a lax.cond in every local step.
        round_mode = fed.hessian_every_unit == "round"
        if round_mode:
            do_h_round = (round_idx % fed.tau) == 0
            h_hat_round = jax.lax.cond(
                do_h_round,
                lambda: cflat.pack(gnb_estimate(
                    task, self._gathered(cflat.unpack(theta, spec)), batch,
                    jax.random.fold_in(rng, 0x7FFFFFFF),
                    vg_fn=self._value_and_grad), spec),
                lambda: cflat.zeros(spec))

        def step(carry, j):
            t, m_, h_ = carry
            loss, g, pg = self._flat_value_and_grad(t, batch, spec)
            if round_mode:
                do_h = do_h_round & (j == 0)   # EMA applied once per refresh
                hh = h_hat_round
            else:
                tstep = round_idx * fed.local_iters + j
                do_h = (tstep % fed.tau) == 0
                rng_j = jax.random.fold_in(rng, j)
                hh = jax.lax.cond(
                    do_h,
                    lambda: cflat.pack(gnb_estimate(
                        task, pg, batch, rng_j,
                        vg_fn=self._value_and_grad), spec),
                    lambda: cflat.zeros(spec))
            t, m_, h_ = sophia.sophia_step_flat(
                t, m_, h_, g, hh, do_h,
                lr=lr, beta1=fed.beta1, beta2=fed.beta2, rho=fed.rho,
                eps=fed.eps, weight_decay=fed.weight_decay,
                use_pallas=fed.use_pallas)
            return (t, m_, h_), loss

        (theta, m, h), losses = jax.lax.scan(
            step, (theta, m, h), jnp.arange(fed.local_iters))
        return theta, m, h, jnp.mean(losses)

    def _local_sophia_flat_batched(self, spec, theta, m, h, batches,
                                   round_idx, rngs, lr):
        """`_local_sophia_flat` for N clients at once: ONE scan over
        local iterations whose body vmaps the loss/grad boundary and
        feeds the (N, rows, cols) state stacks to a single batched
        Sophia kernel launch per iteration.  ``theta`` may be the
        shared (rows, cols) start model or a per-client (N, rows,
        cols) stack (downlink replicas).  scan(vmap(grad)) computes
        exactly what vmap(scan(grad)) would, so this is bitwise equal
        to vmapping the per-client loop."""
        fed = self.fed
        task = self.task
        N = rngs.shape[0]

        round_mode = fed.hessian_every_unit == "round"
        if round_mode:
            do_h_round = (round_idx % fed.tau) == 0
            if theta.ndim == 3:
                def gnb_round():
                    return jax.vmap(
                        lambda t, b, r: cflat.pack(gnb_estimate(
                            task, self._gathered(cflat.unpack(t, spec)),
                            b, jax.random.fold_in(r, 0x7FFFFFFF),
                            vg_fn=self._value_and_grad), spec)
                    )(theta, batches, rngs)
            else:
                # shared start model: ONE unpacked view feeds every
                # client's estimator (what vmap hoists anyway)
                pg0 = self._gathered(cflat.unpack(theta, spec))

                def gnb_round():
                    return jax.vmap(
                        lambda b, r: cflat.pack(gnb_estimate(
                            task, pg0, b,
                            jax.random.fold_in(r, 0x7FFFFFFF),
                            vg_fn=self._value_and_grad), spec)
                    )(batches, rngs)
            h_hat_round = jax.lax.cond(
                do_h_round, gnb_round, lambda: cflat.zeros(spec, (N,)))

        def step(carry, j):
            t, m_, h_ = carry
            losses, g, pgs = jax.vmap(
                lambda tt, bb: self._flat_value_and_grad(tt, bb, spec)
            )(t, batches)
            if round_mode:
                do_h = do_h_round & (j == 0)
                hh = h_hat_round
            else:
                tstep = round_idx * fed.local_iters + j
                do_h = (tstep % fed.tau) == 0
                hh = jax.lax.cond(
                    do_h,
                    lambda: jax.vmap(
                        lambda pg, bb, r: cflat.pack(gnb_estimate(
                            task, pg, bb, jax.random.fold_in(r, j),
                            vg_fn=self._value_and_grad), spec)
                    )(pgs, batches, rngs),
                    lambda: cflat.zeros(spec, (N,)))
            t, m_, h_ = sophia.sophia_step_flat(
                t, m_, h_, g, hh, do_h,
                lr=lr, beta1=fed.beta1, beta2=fed.beta2, rho=fed.rho,
                eps=fed.eps, weight_decay=fed.weight_decay,
                use_pallas=fed.use_pallas)
            return (t, m_, h_), losses

        t0 = (theta if theta.ndim == 3
              else jnp.broadcast_to(theta[None], (N,) + theta.shape))
        (theta, m, h), losses = jax.lax.scan(
            step, (t0, m, h), jnp.arange(fed.local_iters))
        return theta, m, h, jnp.mean(losses, axis=0)

    def _local_sgd_flat(self, spec, theta, batch, rng, lr):
        """Flat-resident local SGD: the update is one flat axpy."""
        def step(t, j):
            loss, g, _ = self._flat_value_and_grad(t, batch, spec)
            return t - lr * g, loss

        theta, losses = jax.lax.scan(step, theta,
                                     jnp.arange(self.fed.local_iters))
        return theta, jnp.mean(losses)

    def _local_sgd_flat_batched(self, spec, theta, batches, rngs, lr):
        """`_local_sgd_flat` for N clients at once (see
        `_local_sophia_flat_batched` for the scan/vmap layout)."""
        N = rngs.shape[0]

        def step(t, j):
            losses, g, _ = jax.vmap(
                lambda tt, bb: self._flat_value_and_grad(tt, bb, spec)
            )(t, batches)
            return t - lr * g, losses

        t0 = (theta if theta.ndim == 3
              else jnp.broadcast_to(theta[None], (N,) + theta.shape))
        theta, losses = jax.lax.scan(step, t0,
                                     jnp.arange(self.fed.local_iters))
        return theta, jnp.mean(losses, axis=0)

    def _local_sgd(self, params, batch, rng, lr):
        """Pytree local SGD — the reference twin of `_local_sgd_flat`
        (bit-identical per coordinate for fp32 models), kept for the
        manual-recomputation equivalence tests."""
        fed = self.fed
        task = self.task

        def step(p, j):
            loss, grads = self._value_and_grad(
                task.loss, self._gathered(p), batch, None)
            p = jax.tree.map(lambda t, g: (t - lr * g).astype(t.dtype),
                             p, grads)
            return p, loss

        params, losses = jax.lax.scan(step, params,
                                      jnp.arange(fed.local_iters))
        return params, jnp.mean(losses)

    def _local_done(self, params, batch, rng, lr):
        """DONE baseline: Richardson iteration for d ~= H^-1 g (HVPs).

        Richardson requires alpha * (lmax + damping) < 2; non-IID clients
        have wildly different local curvature, so alpha is set per client
        from a short power-iteration estimate of lmax.  Inherently a
        pytree algorithm (nested jvp over the loss), so the flat engine
        brackets it with one unpack/pack pair per client round.
        """
        fed = self.fed
        task = self.task
        params_g = self._gathered(params)
        loss, g = jax.value_and_grad(task.loss)(params_g, batch, None)
        grad_fn = lambda p: jax.grad(task.loss)(p, batch, None)

        def hvp(d):
            return jax.jvp(grad_fn, (params_g,), (d,))[1]

        def power(v, _):
            hv = hvp(v)
            nrm = jnp.sqrt(tree_sq_norm(hv)) + 1e-12
            return jax.tree.map(lambda x: x / nrm, hv), nrm

        v0 = jax.tree.map(
            lambda x: x / (jnp.sqrt(tree_sq_norm(g)) + 1e-12), g)
        _, norms = jax.lax.scan(power, v0, None, length=5)
        lmax = norms[-1]
        alpha = 0.9 / (lmax + fed.done_damping)

        def rich(d, _):
            hd = hvp(d)
            # damped Richardson: d += alpha * (g - (H + delta I) d)
            d = jax.tree.map(
                lambda dd, gg, hh: dd + alpha
                * (gg - hh - fed.done_damping * dd), d, g, hd)
            return d, None

        d, _ = jax.lax.scan(rich, tree_zeros_like(params), None,
                            length=fed.done_richardson_iters)
        # trust region: indefinite local Hessians can still blow the
        # Richardson solve up on pathological non-IID clients — cap the
        # Newton step at a multiple of the gradient norm.
        gn = jnp.sqrt(tree_sq_norm(g))
        dn = jnp.sqrt(tree_sq_norm(d))
        cap = jnp.minimum(1.0, 10.0 * gn / (dn + 1e-12))
        new = jax.tree.map(lambda t, dd: (t - lr * cap * dd).astype(t.dtype),
                           params, d)
        return new, loss

    # ------------------------------------------------- one client, dispatch
    def _local_update_flat(self, spec, theta, opt, batch, crng, round_idx,
                           lr):
        """One client's local training over wire-layout state.

        theta: (rows, cols) packed start model; opt: `SophiaState` of
        (rows, cols) buffers or None.  Returns (new_theta,
        new_opt_or_None, mean_loss); new_opt is None for optimizers
        without persistent per-client state.
        """
        fed = self.fed
        if fed.optimizer == "fed_sophia":
            if opt is None:   # stateless: fresh EMAs each round
                opt = sophia.SophiaState(m=cflat.zeros(spec),
                                         h=cflat.zeros(spec))
            t, m, h, loss = self._local_sophia_flat(
                spec, theta, opt.m, opt.h, batch, round_idx, crng, lr)
            opt = sophia.SophiaState(m=m, h=h)
            return t, (opt if fed.persistent_client_state else None), loss
        if fed.optimizer in ("fedavg", "fedadam", "fedyogi"):
            t, loss = self._local_sgd_flat(spec, theta, batch, crng, lr)
            return t, None, loss
        if fed.optimizer == "done":
            p, loss = self._local_done(cflat.unpack(theta, spec), batch,
                                       crng, lr)
            return cflat.pack(p, spec), None, loss
        raise ValueError(fed.optimizer)

    def _local_update_flat_batched(self, spec, theta, opts, batches,
                                   crngs, round_idx, lr):
        """`_local_update_flat` for the whole cohort: per-client state
        carries a leading client axis N; ``theta`` may be the shared
        (rows, cols) start model or a per-client (N, rows, cols)
        stack.  fed_sophia / fedavg-family run the batched flat loops
        (one kernel launch per iteration for the whole cohort); done
        is inherently a pytree algorithm, so it stays a vmap of the
        per-client step."""
        fed = self.fed
        N = crngs.shape[0]
        if fed.optimizer == "fed_sophia":
            if opts is None:   # stateless: fresh EMAs each round
                opts = sophia.SophiaState(m=cflat.zeros(spec, (N,)),
                                          h=cflat.zeros(spec, (N,)))
            t, m, h, loss = self._local_sophia_flat_batched(
                spec, theta, opts.m, opts.h, batches, round_idx, crngs,
                lr)
            opt = sophia.SophiaState(m=m, h=h)
            return t, (opt if fed.persistent_client_state else None), loss
        if fed.optimizer in ("fedavg", "fedadam", "fedyogi"):
            t, loss = self._local_sgd_flat_batched(spec, theta, batches,
                                                   crngs, lr)
            return t, None, loss
        theta_ax = None if theta.ndim == 2 else 0
        return jax.vmap(
            lambda t, b, r: self._local_update_flat(
                spec, t, None, b, r, round_idx, lr),
            in_axes=(theta_ax, 0, 0))(theta, batches, crngs)

    def _apply_aggregate(self, state, agg):
        """Server step on the aggregated params-space model `agg`."""
        if self.fed.optimizer in ("fedadam", "fedyogi"):
            return self._server_opt_update(state, agg)
        return {**state, "params": agg}

    def _apply_aggregate_flat(self, state, agg_flat):
        """`_apply_aggregate` for packed-resident state: the server
        model update never leaves wire layout (stored back in the
        resident dtype)."""
        if self.fed.optimizer in ("fedadam", "fedyogi"):
            return self._server_opt_update_flat(state, agg_flat)
        return {**state,
                "params": agg_flat.astype(state["params"].dtype)}

    # ------------------------------------------------------------- the round
    def round(self, state, batches, rng):
        """batches: pytree with leading client axis C. Returns (state, metrics).

        Accepts either residency: tree-resident state (`init`) or
        packed-resident state (`pack_state`) — the latter skips the
        per-round params pack/unpack bracket entirely.  Jit through
        `round_fn` to opt into buffer donation (in-place resident
        state)."""
        fed = self.fed
        comm = fed.comm
        round_idx = state["round"]
        lr = lr_at_round(fed, round_idx)
        C = fed.num_clients
        S = comm.num_participants(C)
        rt = self.runtime_for(state["params"])
        client_rngs = jax.vmap(lambda i: jax.random.fold_in(rng, i))(
            jnp.arange(C))

        if self.uses_direct_path():
            # lossless identity at full participation, no extra streams:
            # aggregate client params directly — bit-identical to the
            # pre-comm engine
            state, loss = self._round_direct(state, batches, client_rngs,
                                             round_idx, lr, rt)
        else:
            state, loss = self._round_comm(state, batches, client_rngs,
                                           round_idx, lr, rng, rt)

        state = {**state, "round": round_idx + 1}
        n = self.num_params(state)
        wire = accounting.round_bytes(comm, n, C)
        metrics = {"loss": loss, "lr": lr,
                   "participants": jnp.asarray(S, jnp.float32)}
        for k in ("uplink_bytes", "downlink_bytes", "hessian_uplink_bytes",
                  "hessian_downlink_bytes", "total_bytes"):
            metrics[k] = jnp.asarray(wire[k], jnp.float32)
        if fed.obs.probes:
            # Sophia health probes, computed INSIDE this jit: pure
            # elementwise/reduction reads of the state the round just
            # produced — no layout ops, no extra host syncs, and the
            # returned state is bitwise identical to the unprobed round
            # (pinned by tests/test_obs.py)
            metrics.update(obs_probes.sophia_health(
                state["client_opt"], round_idx, fed, rt.spec.total))
        return state, metrics

    def probe_metrics(self, state) -> Dict[str, jnp.ndarray]:
        """The Sophia health probes of `repro.obs.probes` for a state
        OUTSIDE the round jit — the virtual-time scheduler applies
        aggregates through its own jits, so it probes the post-apply
        state with this (jittable; requires the stateful engine)."""
        if not self._stateful():
            raise ValueError(
                "probe_metrics reads the persistent Sophia m/h EMAs: "
                "it requires optimizer='fed_sophia' with "
                "persistent_client_state=True")
        rt = self.runtime_for(state["params"])
        return obs_probes.sophia_health(
            state["client_opt"], state["round"] - 1, self.fed,
            rt.spec.total)

    def _round_direct(self, state, batches, client_rngs, round_idx, lr, rt):
        """Original aggregation: server model <- mean of client params —
        computed entirely in wire layout (ONE pack of the server model
        in, ONE unpack of the aggregate out — and ZERO of either in
        packed-resident mode).  Resident rows feed the local loops in
        their storage dtype (the kernels upcast loads in-VMEM) and
        downcast on the store back (no-ops for fp32 state)."""
        fed = self.fed
        spec = rt.spec
        params = state["params"]
        C = fed.num_clients
        stateful = self._stateful()
        packed = self.params_packed(params)
        theta = (params.astype(jnp.float32) if packed
                 else cflat.pack(params, spec))
        opts = state.get("client_opt") if stateful else None

        # adversarial fleet (repro.robust): both knobs are static
        # config — when off, neither branch below enters the traced
        # graph and the round is bitwise the historical mean path
        rb = fed.robust
        attack_on = robust_attacks.wire_attack_active(rb, C)
        robust_on = robust_agg.resolve(rb, C) != "mean"
        adversarial = attack_on or robust_on

        if fed.strategy == "parallel":
            # the whole cohort steps through the batched flat loop —
            # one kernel launch per local iteration over (C, rows,
            # cols) stacks
            new_t, new_opt, losses = self._local_update_flat_batched(
                spec, theta, opts, batches, client_rngs, round_idx, lr)
            if not adversarial:
                agg_flat = jnp.mean(new_t, axis=0)
        elif adversarial:
            # robust/attacked sequential: the scan stacks each
            # client's params (same memory as the parallel stack —
            # trimming needs the whole cohort at once)
            def scan_collect(_, xs):
                opt, batch, crng = xs
                t_i, opt_i, loss = self._local_update_flat(
                    spec, theta, opt, batch, crng, round_idx, lr)
                return 0, (t_i, opt_i, loss)
            _, (new_t, new_opt, losses) = jax.lax.scan(
                scan_collect, 0, (opts, batches, client_rngs))
        else:
            def scan_body(acc, xs):
                opt, batch, crng = xs
                t_i, opt_i, loss = self._local_update_flat(
                    spec, theta, opt, batch, crng, round_idx, lr)
                return acc + t_i / C, (opt_i, loss)
            agg_flat, (new_opt, losses) = jax.lax.scan(
                scan_body, jnp.zeros_like(theta),
                (opts, batches, client_rngs))

        if adversarial:
            # the direct path carries whole client models; attacks and
            # robust combination are defined on the *contribution
            # delta* vs the round-start model — equivalent to the wire
            # transforms of the comm path on an uncompressed uplink
            deltas = new_t - theta
            if attack_on:
                deltas = robust_attacks.attack_wires(
                    rb, deltas,
                    jnp.asarray(robust_attacks.byzantine_mask(rb, C)),
                    client_rngs[0])
            agg_flat = theta + robust_agg.aggregate_stack(
                rb, deltas, jnp.ones((C,), jnp.float32),
                normalize=True, use_pallas=fed.comm.use_pallas,
                interpret=_INTERPRET)

        if packed:
            state = self._apply_aggregate_flat(state, agg_flat)
        else:
            state = self._apply_aggregate(state,
                                          cflat.unpack(agg_flat, spec))
        if stateful:
            state = {**state, "client_opt": self._store_opt(new_opt)}
        return state, jnp.mean(losses)

    def _round_comm(self, state, batches, client_rngs, round_idx, lr, rng,
                    rt):
        """Multi-stream delta-space round (docs/architecture.md):

        1. [downlink] each participant receives the compressed delta of
           the server model vs its own last-received replica (server-side
           per-client EF) and trains from what it actually received;
        2. [uplink] its model delta vs that replica is compressed (with
           optional client EF), decoded server-side, and the decoded wire
           payloads are aggregated weighted by participation;
        3. [hessian] optionally, its Sophia h-EMA is compressed and
           uploaded; the server averages the curvature and broadcasts
           one common payload back, re-syncing the participants' h.

        With the downlink/hessian streams disabled, steps 1 and 3
        vanish from the traced graph and the round is the PR-1 uplink
        pipeline unchanged.  Participation is a gather: only the S
        sampled clients run local training (their rows are gathered up
        front and their state rows scattered back), so partial
        participation saves real compute in both strategies instead of
        masking discarded work.
        """
        fed = self.fed
        comm = fed.comm
        params = state["params"]
        C = fed.num_clients
        S = comm.num_participants(C)
        spec, comp = rt.spec, rt.comp
        dn_on, h_on = rt.dn_on, rt.h_on
        packed = self.params_packed(params)
        theta = (params.astype(jnp.float32) if packed
                 else cflat.pack(params, spec))
        theta_dn = cflat.repack(theta, spec, rt.spec_dn) if dn_on else None
        idx = participation_indices(
            jax.random.fold_in(rng, PARTICIPATION_SALT + comm.seed), C, S)
        stateful = self._stateful()
        opts = state.get("client_opt") if stateful else None
        ef = state.get("comm_ef")
        dn_model = state.get(cdown.MODEL_KEY)
        dn_ef = state.get(cdown.EF_KEY)

        def take(tree):
            # gathered rows stay in the resident storage dtype — the
            # kernels upcast loads in-VMEM (no bulk fp32 copy)
            return (None if tree is None
                    else jax.tree.map(lambda x: x[idx], tree))

        opts_g, ef_g = take(opts), take(ef)
        dnm_g, dnef_g = take(dn_model), take(dn_ef)
        batches_g, rngs_g = take(batches), client_rngs[idx]

        client = functools.partial(self.comm_client_step, rt, theta,
                                   theta_dn, round_idx, lr)

        # adversarial fleet (repro.robust): static config — when off,
        # the attack/robust branches never enter the traced graph and
        # the aggregation below is the historical weighted-mean path.
        # Attacks transform the packed uplink wire buffer only; the
        # downlink-replica correction and hessian streams keep their
        # participation means (docs/robustness.md).
        rb = fed.robust
        attack_on = robust_attacks.wire_attack_active(rb, C)
        robust_on = robust_agg.resolve(rb, S) != "mean"

        def combine_wires(wires):
            if attack_on:
                byz = jnp.asarray(robust_attacks.byzantine_mask(rb, C))
                wires = robust_attacks.attack_wires(rb, wires, byz[idx],
                                                    rng)
            if robust_on:
                return robust_agg.aggregate_stack(
                    rb, wires, jnp.ones((S,), jnp.float32),
                    normalize=True, use_pallas=comm.use_pallas,
                    interpret=_INTERPRET)
            return jnp.sum(wires, axis=0) / S

        if fed.strategy == "parallel":
            (wires, stats, ef_new_g, opt_new_g, losses, dnm_new_g,
             dnef_new_g, h_hat_g, h_stat_g) = self.comm_client_step_batched(
                rt, theta, theta_dn, round_idx, lr,
                opts_g, ef_g, dnm_g, dnef_g, batches_g, rngs_g)
            agg_flat = combine_wires(wires)
            wstat = jnp.sum(stats) / S
            if dn_on:
                dn_mean = jnp.sum(dnm_new_g, axis=0) / S
            if h_on:
                h_agg = jnp.sum(h_hat_g, axis=0) / S
                h_wstat = jnp.sum(h_stat_g) / S
        else:
            collect = attack_on or robust_on

            def scan_body(acc, xs):
                opt, ef_i, dnm_i, dnef_i, batch, crng = xs
                (wire, stat, ef_i_new, opt_i, loss, dnm_new, dnef_new,
                 h_hat, h_stat) = client(opt, ef_i, dnm_i, dnef_i,
                                         batch, crng)
                # robust/attacked runs stack the wires (trimming needs
                # the whole cohort) instead of accumulating the mean
                if not collect:
                    acc = {**acc, "w": acc["w"] + wire / S}
                acc = {**acc, "s": acc["s"] + stat / S}
                if dn_on:
                    acc = {**acc, "dn": acc["dn"] + dnm_new / S}
                if h_on:
                    acc = {**acc, "h": acc["h"] + h_hat / S,
                           "hs": acc["hs"] + h_stat / S}
                ys = (ef_i_new, opt_i, loss, dnm_new, dnef_new)
                return acc, (ys + (wire,)) if collect else ys
            acc0 = {"s": jnp.zeros((), jnp.float32)}
            if not collect:
                acc0["w"] = cflat.zeros(spec)
            if dn_on:
                acc0["dn"] = cflat.zeros(rt.spec_dn)
            if h_on:
                acc0["h"] = cflat.zeros(rt.spec_h)
                acc0["hs"] = jnp.zeros((), jnp.float32)
            acc, ys = jax.lax.scan(scan_body, acc0,
                                   (opts_g, ef_g, dnm_g, dnef_g,
                                    batches_g, rngs_g))
            (ef_new_g, opt_new_g, losses, dnm_new_g, dnef_new_g) = ys[:5]
            agg_flat = combine_wires(ys[5]) if collect else acc["w"]
            wstat = acc["s"]
            if dn_on:
                dn_mean = acc["dn"]
            if h_on:
                h_agg, h_wstat = acc["h"], acc["hs"]

        agg_flat = comp.server_combine(agg_flat, wstat)
        if dn_on:
            # clients trained from their OWN received replicas: the
            # aggregated model is mean_S(replica + decoded uplink delta),
            # expressed as a server-side delta vs the true model
            corr = cflat.repack(dn_mean - theta_dn, rt.spec_dn, spec)
            agg_flat = agg_flat + corr
        # the server model update is a flat axpy; the pytree appears
        # only at the state boundary (and not at all in packed-
        # resident mode)
        if packed:
            state = self._apply_aggregate_flat(state, theta + agg_flat)
        else:
            state = self._apply_aggregate(
                state, cflat.unpack(theta + agg_flat, spec))
        if stateful:
            # scatter the participants' optimizer state rows back
            # (downcast to the per-buffer resident dtypes; no-op for
            # fp32)
            new_opts = jax.tree.map(
                lambda full, g: full.at[idx].set(g),
                state["client_opt"], self._store_opt(opt_new_g))
            if h_on:
                # curvature averaging: every participant's h re-synced
                # to the (re-quantized) common averaged broadcast
                h_down, _ = rt.comp_h.roundtrip(
                    jax.random.fold_in(rng, 0x4D),
                    rt.comp_h.server_combine(h_agg, h_wstat))
                h_common = cflat.repack(h_down, rt.spec_h, spec).astype(
                    new_opts.h.dtype)
                new_opts = new_opts._replace(h=new_opts.h.at[idx].set(
                    jnp.broadcast_to(h_common[None],
                                     (S,) + h_common.shape)))
            state = {**state, "client_opt": new_opts}
        if ef is not None:
            state = {**state, "comm_ef":
                     ef.at[idx].set(self._store(ef_new_g))}
        if dn_model is not None:
            state = {**state, cdown.MODEL_KEY:
                     dn_model.at[idx].set(self._store(dnm_new_g))}
        if dn_ef is not None:
            state = {**state, cdown.EF_KEY:
                     dn_ef.at[idx].set(self._store(dnef_new_g))}
        return state, jnp.mean(losses)

    # ------------------------------------------------ server-side optimizers
    def _server_opt_update(self, state, agg):
        """FedOpt family: Delta = params - mean(client params) is the
        pseudo-gradient; apply Adam/Yogi on the server."""
        fed = self.fed
        params = state["params"]
        so = state["server_opt"]
        delta = jax.tree.map(jnp.subtract, params, agg)
        m = jax.tree.map(lambda mm, d: fed.server_beta1 * mm
                         + (1 - fed.server_beta1) * d, so["m"], delta)
        if fed.optimizer == "fedadam":
            v = jax.tree.map(lambda vv, d: fed.server_beta2 * vv
                             + (1 - fed.server_beta2) * d * d, so["v"], delta)
        else:  # fedyogi
            v = jax.tree.map(
                lambda vv, d: vv - (1 - fed.server_beta2) * d * d
                * jnp.sign(vv - d * d), so["v"], delta)
        new_params = jax.tree.map(
            lambda p, mm, vv: (p - fed.server_lr * mm
                               / (jnp.sqrt(vv) + fed.server_eps)).astype(p.dtype),
            params, m, v)
        return {**state, "params": new_params,
                "server_opt": {"m": m, "v": v}}

    def _server_opt_update_flat(self, state, agg):
        """`_server_opt_update` over packed wire buffers (packed-
        resident mode): identical per-coordinate math on the flattened
        coordinates, fp32 compute, stored back in the resident dtype.
        ``agg`` is the fp32 aggregated packed model."""
        fed = self.fed
        so = state["server_opt"]
        params = state["params"].astype(jnp.float32)
        m0, v0 = (so["m"].astype(jnp.float32),
                  so["v"].astype(jnp.float32))
        delta = params - agg
        m = fed.server_beta1 * m0 + (1 - fed.server_beta1) * delta
        if fed.optimizer == "fedadam":
            v = (fed.server_beta2 * v0
                 + (1 - fed.server_beta2) * delta * delta)
        else:  # fedyogi
            v = v0 - ((1 - fed.server_beta2) * delta * delta
                      * jnp.sign(v0 - delta * delta))
        new_params = (params - fed.server_lr * m
                      / (jnp.sqrt(v) + fed.server_eps))
        return {**state,
                "params": new_params.astype(state["params"].dtype),
                "server_opt": {"m": m.astype(so["m"].dtype),
                               "v": v.astype(so["v"].dtype)}}
