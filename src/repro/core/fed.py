"""Federated runtime: one jitted call = one communication round (Alg. 1).

Two execution strategies (DESIGN.md §4):
  * parallel   — vmap over a leading client axis; client axis is sharded
                 along the mesh 'data' (and 'pod') axes, so the final
                 aggregation mean lowers to the cross-client all-reduce
                 that realises Eq. 4.
  * sequential — lax.scan over clients; each client trains with the whole
                 mesh (FSDP); memory O(1) in the number of clients.

Optimizers: fed_sophia (the paper), fedavg, done, fedadam, fedyogi.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import sophia
from repro.core.gnb import gnb_estimate
from repro.core.schedules import lr_at_round
from repro.utils.tree import tree_mean_axis0, tree_sq_norm, tree_zeros_like


class FedEngine:
    def __init__(self, task, fed: FedConfig, gather_shardings=None):
        self.task = task
        self.fed = fed
        # FSDP (sequential strategy): params are STORED sharded over the
        # data axes; each use must see them model-only-sharded, otherwise
        # GSPMD resolves the data-axis contraction by replicating the
        # batch-sharded activations instead (16x activation traffic).
        # gather_shardings = model-only NamedSharding pytree; constraining
        # params to it at each local step lowers to the per-step weight
        # all-gather that defines FSDP/ZeRO-3.
        self.gather_shardings = gather_shardings

    def _gathered(self, params):
        if self.gather_shardings is None:
            return params
        return jax.tree.map(jax.lax.with_sharding_constraint, params,
                            self.gather_shardings)

    def _value_and_grad(self, loss_fn, params, batch, rng=None):
        """value_and_grad with optional exact micro-batch accumulation."""
        n = self.fed.grad_microbatches
        if n <= 1:
            return jax.value_and_grad(loss_fn)(params, batch, rng)
        mb = jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

        def body(acc, xs):
            i, b = xs
            r = jax.random.fold_in(rng, i) if rng is not None else None
            l, g = jax.value_and_grad(loss_fn)(params, b, r)
            acc = (acc[0] + l / n,
                   jax.tree.map(lambda a, gg: a + gg / n, acc[1], g))
            return acc, None

        init = (jnp.zeros((), jnp.float32), tree_zeros_like(params))
        (loss, grads), _ = jax.lax.scan(
            body, init, (jnp.arange(n), mb))
        return loss, grads

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        params = self.task.init(key)
        state: Dict[str, Any] = {"params": params, "round": jnp.zeros((), jnp.int32)}
        if (self.fed.optimizer == "fed_sophia"
                and self.fed.persistent_client_state):
            opt = sophia.init_state(params)
            state["client_opt"] = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (self.fed.num_clients,) + x.shape).copy(), opt)
        if self.fed.optimizer in ("fedadam", "fedyogi"):
            state["server_opt"] = {"m": tree_zeros_like(params),
                                   "v": tree_zeros_like(params)}
        return state

    # ------------------------------------------------- local client training
    def _local_sophia(self, params, opt, batch, round_idx, rng, lr):
        fed = self.fed
        task = self.task

        # round mode (Alg. 1 line 9 literal: refresh when k mod tau == 0):
        # the GNB estimate uses the round-start params, so it hoists out of
        # the local-iteration scan — one estimator call per refresh round
        # instead of a lax.cond in every local step.
        round_mode = fed.hessian_every_unit == "round"
        if round_mode:
            do_h_round = (round_idx % fed.tau) == 0
            h_hat_round = jax.lax.cond(
                do_h_round,
                lambda: gnb_estimate(task, self._gathered(params), batch,
                                     jax.random.fold_in(rng, 0x7FFFFFFF),
                                     vg_fn=self._value_and_grad),
                lambda: tree_zeros_like(params))

        def step(carry, j):
            p, st = carry
            pg = self._gathered(p)          # FSDP: model-only view for use
            loss, grads = self._value_and_grad(task.loss, pg, batch, None)
            if round_mode:
                do_h = do_h_round & (j == 0)   # EMA applied once per refresh
                h_hat = h_hat_round
            else:
                t = round_idx * fed.local_iters + j
                do_h = (t % fed.tau) == 0
                rng_j = jax.random.fold_in(rng, j)
                h_hat = jax.lax.cond(
                    do_h,
                    lambda: gnb_estimate(task, pg, batch, rng_j,
                                         vg_fn=self._value_and_grad),
                    lambda: tree_zeros_like(p))
            p, st = sophia.sophia_step(
                p, grads, st, h_hat, do_h,
                lr=lr, beta1=fed.beta1, beta2=fed.beta2, rho=fed.rho,
                eps=fed.eps, weight_decay=fed.weight_decay,
                use_pallas=fed.use_pallas)
            return (p, st), loss

        (params, opt), losses = jax.lax.scan(
            step, (params, opt), jnp.arange(fed.local_iters))
        return params, opt, jnp.mean(losses)

    def _local_sgd(self, params, batch, rng, lr):
        fed = self.fed
        task = self.task

        def step(p, j):
            loss, grads = self._value_and_grad(
                task.loss, self._gathered(p), batch, None)
            p = jax.tree.map(lambda t, g: (t - lr * g).astype(t.dtype),
                             p, grads)
            return p, loss

        params, losses = jax.lax.scan(step, params, jnp.arange(fed.local_iters))
        return params, jnp.mean(losses)

    def _local_done(self, params, batch, rng, lr):
        """DONE baseline: Richardson iteration for d ~= H^-1 g (HVPs).

        Richardson requires alpha * (lmax + damping) < 2; non-IID clients
        have wildly different local curvature, so alpha is set per client
        from a short power-iteration estimate of lmax.
        """
        fed = self.fed
        task = self.task
        params_g = self._gathered(params)
        loss, g = jax.value_and_grad(task.loss)(params_g, batch, None)
        grad_fn = lambda p: jax.grad(task.loss)(p, batch, None)

        def hvp(d):
            return jax.jvp(grad_fn, (params_g,), (d,))[1]

        def power(v, _):
            hv = hvp(v)
            nrm = jnp.sqrt(tree_sq_norm(hv)) + 1e-12
            return jax.tree.map(lambda x: x / nrm, hv), nrm

        v0 = jax.tree.map(
            lambda x: x / (jnp.sqrt(tree_sq_norm(g)) + 1e-12), g)
        _, norms = jax.lax.scan(power, v0, None, length=5)
        lmax = norms[-1]
        alpha = 0.9 / (lmax + fed.done_damping)

        def rich(d, _):
            hd = hvp(d)
            # damped Richardson: d += alpha * (g - (H + delta I) d)
            d = jax.tree.map(
                lambda dd, gg, hh: dd + alpha
                * (gg - hh - fed.done_damping * dd), d, g, hd)
            return d, None

        d, _ = jax.lax.scan(rich, tree_zeros_like(params), None,
                            length=fed.done_richardson_iters)
        # trust region: indefinite local Hessians can still blow the
        # Richardson solve up on pathological non-IID clients — cap the
        # Newton step at a multiple of the gradient norm.
        gn = jnp.sqrt(tree_sq_norm(g))
        dn = jnp.sqrt(tree_sq_norm(d))
        cap = jnp.minimum(1.0, 10.0 * gn / (dn + 1e-12))
        new = jax.tree.map(lambda t, dd: (t - lr * cap * dd).astype(t.dtype),
                           params, d)
        return new, loss

    # ------------------------------------------------------------- the round
    def round(self, state, batches, rng):
        """batches: pytree with leading client axis C. Returns (state, metrics)."""
        fed = self.fed
        round_idx = state["round"]
        lr = lr_at_round(fed, round_idx)
        params = state["params"]
        C = fed.num_clients
        client_rngs = jax.vmap(lambda i: jax.random.fold_in(rng, i))(
            jnp.arange(C))

        if fed.optimizer == "fed_sophia":
            stateful = fed.persistent_client_state

            def one(opt, batch, crng):
                if opt is None:   # stateless: fresh EMAs each round
                    opt = sophia.init_state(params)
                return self._local_sophia(params, opt, batch, round_idx,
                                          crng, lr)
            if fed.strategy == "parallel":
                if stateful:
                    new_p, new_opt, losses = jax.vmap(one)(
                        state["client_opt"], batches, client_rngs)
                else:
                    new_p, _, losses = jax.vmap(
                        lambda b, r: one(None, b, r))(batches, client_rngs)
                agg = tree_mean_axis0(new_p)
            else:
                def scan_body(acc, xs):
                    if stateful:
                        opt, batch, crng = xs
                    else:
                        batch, crng = xs
                        opt = None
                    p_i, opt_i, loss = one(opt, batch, crng)
                    acc = jax.tree.map(lambda a, x: a + x / C, acc, p_i)
                    return acc, ((opt_i, loss) if stateful else loss)
                xs = ((state["client_opt"], batches, client_rngs)
                      if stateful else (batches, client_rngs))
                agg, ys = jax.lax.scan(scan_body, tree_zeros_like(params), xs)
                new_opt, losses = ys if stateful else (None, ys)
                agg = jax.tree.map(lambda a, p: a.astype(p.dtype), agg, params)
            state = {**state, "params": agg}
            if stateful:
                state["client_opt"] = new_opt

        elif fed.optimizer in ("fedavg", "fedadam", "fedyogi"):
            def one(batch, crng):
                return self._local_sgd(params, batch, crng, lr)
            if fed.strategy == "parallel":
                new_p, losses = jax.vmap(one)(batches, client_rngs)
                agg = tree_mean_axis0(new_p)
            else:
                def scan_body(acc, xs):
                    batch, crng = xs
                    p_i, loss = one(batch, crng)
                    return jax.tree.map(lambda a, x: a + x / C, acc, p_i), loss
                agg, losses = jax.lax.scan(
                    scan_body, tree_zeros_like(params), (batches, client_rngs))
                agg = jax.tree.map(lambda a, p: a.astype(p.dtype), agg, params)
            if fed.optimizer == "fedavg":
                state = {**state, "params": agg}
            else:
                state = self._server_opt_update(state, agg)

        elif fed.optimizer == "done":
            def one(batch, crng):
                return self._local_done(params, batch, crng, lr)
            if fed.strategy == "parallel":
                new_p, losses = jax.vmap(one)(batches, client_rngs)
                agg = tree_mean_axis0(new_p)
            else:
                def scan_body(acc, xs):
                    batch, crng = xs
                    p_i, loss = one(batch, crng)
                    return jax.tree.map(lambda a, x: a + x / C, acc, p_i), loss
                agg, losses = jax.lax.scan(
                    scan_body, tree_zeros_like(params), (batches, client_rngs))
                agg = jax.tree.map(lambda a, p: a.astype(p.dtype), agg, params)
            state = {**state, "params": agg}
        else:
            raise ValueError(fed.optimizer)

        state["round"] = round_idx + 1
        metrics = {"loss": jnp.mean(losses), "lr": lr}
        return state, metrics

    # ------------------------------------------------ server-side optimizers
    def _server_opt_update(self, state, agg):
        """FedOpt family: Delta = params - mean(client params) is the
        pseudo-gradient; apply Adam/Yogi on the server."""
        fed = self.fed
        params = state["params"]
        so = state["server_opt"]
        delta = jax.tree.map(jnp.subtract, params, agg)
        m = jax.tree.map(lambda mm, d: fed.server_beta1 * mm
                         + (1 - fed.server_beta1) * d, so["m"], delta)
        if fed.optimizer == "fedadam":
            v = jax.tree.map(lambda vv, d: fed.server_beta2 * vv
                             + (1 - fed.server_beta2) * d * d, so["v"], delta)
        else:  # fedyogi
            v = jax.tree.map(
                lambda vv, d: vv - (1 - fed.server_beta2) * d * d
                * jnp.sign(vv - d * d), so["v"], delta)
        new_params = jax.tree.map(
            lambda p, mm, vv: (p - fed.server_lr * mm
                               / (jnp.sqrt(vv) + fed.server_eps)).astype(p.dtype),
            params, m, v)
        return {**state, "params": new_params,
                "server_opt": {"m": m, "v": v}}
