"""Gauss-Newton-Bartlett diagonal Hessian estimator (Alg. 2).

    1. compute logits phi(theta, x_b) on the minibatch
    2. sample y_b ~ softmax(logits)
    3. g_hat = grad of (1/B) sum CE(logits, y_b)   w.r.t. theta
    4. h_hat = B * g_hat ⊙ g_hat

The sampled labels are stop-gradient'd; the backward pass reuses the same
graph as the training loss, so GSPMD partitions it identically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gnb_estimate(task, params, batch, rng, vg_fn=None):
    """Returns the h_hat pytree (same structure as params).

    vg_fn: optional (loss_fn, params, batch, rng) -> (loss, grads), used by
    the engine to micro-batch the estimator backward pass (exact — g_hat is
    the mean over the full minibatch either way)."""
    if vg_fn is None:
        g_hat = jax.grad(task.sampled_loss)(params, batch, rng)
    else:
        _, g_hat = vg_fn(task.sampled_loss, params, batch, rng)
    B = task.gnb_batch_size(batch)
    return jax.tree.map(lambda g: B * g * g, g_hat)
