"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; suite degrades gracefully")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import sophia
from repro.kernels.ref import sophia_update_ref
from repro.kernels.sophia_update import sophia_update_flat

SETTINGS = dict(max_examples=25, deadline=None)

floats = st.floats(min_value=-100.0, max_value=100.0, allow_nan=False,
                   width=32)
pos_floats = st.floats(min_value=0.0, max_value=100.0, allow_nan=False,
                       width=32)


@settings(**SETTINGS)
@given(z=hnp.arrays(np.float32, hnp.array_shapes(max_dims=2, max_side=16),
                    elements=floats),
       rho=st.floats(min_value=0.0009765625, max_value=1.0, width=32))
def test_clip_is_bounded_and_idempotent(z, rho):
    out = sophia.clip(jnp.asarray(z), rho)
    assert np.all(np.abs(np.asarray(out)) <= rho + 1e-7)
    np.testing.assert_array_equal(np.asarray(sophia.clip(out, rho)),
                                  np.asarray(out))


@settings(**SETTINGS)
@given(m0=floats, g=floats, b1=st.floats(min_value=0.0, max_value=1.0,
                                         width=32))
def test_m_ema_convex_combination(m0, g, b1):
    out = float(sophia.update_m({"x": jnp.float32(m0)},
                                {"x": jnp.float32(g)}, b1)["x"])
    lo, hi = min(m0, g), max(m0, g)
    assert lo - 1e-3 <= out <= hi + 1e-3


@settings(**SETTINGS)
@given(theta=hnp.arrays(np.float32, (8, 128), elements=floats),
       g=hnp.arrays(np.float32, (8, 128), elements=floats),
       hh=hnp.arrays(np.float32, (8, 128), elements=pos_floats),
       lr=st.floats(min_value=7.62939453125e-06, max_value=0.125, width=32),
       do_h=st.sampled_from([0.0, 1.0]))
def test_kernel_equals_oracle_property(theta, g, hh, lr, do_h):
    """Pallas kernel == oracle for arbitrary inputs (the per-kernel
    allclose requirement, driven by hypothesis)."""
    m = 0.1 * g
    h = 0.5 * hh
    hp = dict(beta1=0.9, beta2=0.95, rho=0.04, eps=1e-12, weight_decay=1e-4)
    out = sophia_update_flat(jnp.asarray(theta), jnp.asarray(m),
                             jnp.asarray(h), jnp.asarray(g),
                             jnp.asarray(hh), do_h, lr, interpret=True, **hp)
    ref = sophia_update_ref(theta, m, h, g, hh, do_h, lr=lr, **hp)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(theta=hnp.arrays(np.float32, (4, 16), elements=floats),
       lr=st.floats(min_value=7.62939453125e-06, max_value=0.125, width=32),
       rho=st.floats(min_value=0.0009765625, max_value=1.0, width=32))
def test_update_bounded_step_property(theta, lr, rho):
    """Paper's guarantee: per-coordinate move (beyond weight decay) is
    bounded by lr * rho regardless of gradient/Hessian values."""
    key = jax.random.PRNGKey(0)
    m = 100.0 * jax.random.normal(key, theta.shape)
    h = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), theta.shape))
    out = sophia.apply_update({"t": jnp.asarray(theta)}, {"t": m}, {"t": h},
                              lr=lr, rho=rho, eps=1e-12, weight_decay=0.0)
    delta = np.abs(np.asarray(out["t"]) - theta)
    # allow one ulp of theta for the float32 subtract
    assert np.all(delta <= lr * rho * (1 + 1e-5) + 1e-5 * np.abs(theta) + 1e-6)


@settings(**SETTINGS)
@given(vals=hnp.arrays(np.float32, (3, 5, 7), elements=floats))
def test_aggregation_mean_bounds(vals):
    """Server aggregate lies in the per-coordinate convex hull of client
    params (Eq. 4 sanity)."""
    from repro.utils.tree import tree_mean_axis0
    agg = np.asarray(tree_mean_axis0({"w": jnp.asarray(vals)})["w"])
    assert np.all(agg <= vals.max(axis=0) + 1e-5)
    assert np.all(agg >= vals.min(axis=0) - 1e-5)
