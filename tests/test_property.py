"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; suite degrades gracefully")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.comm import compressors as ccomp
from repro.comm import flat as cflat
from repro.configs.base import CommConfig
from repro.core import sophia
from repro.kernels.ref import sophia_update_ref
from repro.kernels.sophia_update import sophia_update_flat

SETTINGS = dict(max_examples=25, deadline=None)

#: small fixed geometry pool: every (total, cols) is a distinct jit
#: compile, so the strategies sample shapes from here and let the
#: seeds/dtypes/paths roam free
GEOMETRIES = [(40, 8), (100, 32), (7, 5)]


def _make(compressor: str, total: int, cols: int, use_pallas: bool,
          **kw) -> ccomp.Compressor:
    spec = cflat.flat_spec({"w": jnp.zeros((total,))}, cols=cols)
    return ccomp.make_compressor(
        CommConfig(compressor=compressor, use_pallas=use_pallas, **kw),
        spec)

floats = st.floats(min_value=-100.0, max_value=100.0, allow_nan=False,
                   width=32)
pos_floats = st.floats(min_value=0.0, max_value=100.0, allow_nan=False,
                       width=32)


@settings(**SETTINGS)
@given(z=hnp.arrays(np.float32, hnp.array_shapes(max_dims=2, max_side=16),
                    elements=floats),
       rho=st.floats(min_value=0.0009765625, max_value=1.0, width=32))
def test_clip_is_bounded_and_idempotent(z, rho):
    out = sophia.clip(jnp.asarray(z), rho)
    assert np.all(np.abs(np.asarray(out)) <= rho + 1e-7)
    np.testing.assert_array_equal(np.asarray(sophia.clip(out, rho)),
                                  np.asarray(out))


@settings(**SETTINGS)
@given(m0=floats, g=floats, b1=st.floats(min_value=0.0, max_value=1.0,
                                         width=32))
def test_m_ema_convex_combination(m0, g, b1):
    out = float(sophia.update_m({"x": jnp.float32(m0)},
                                {"x": jnp.float32(g)}, b1)["x"])
    lo, hi = min(m0, g), max(m0, g)
    assert lo - 1e-3 <= out <= hi + 1e-3


@settings(**SETTINGS)
@given(theta=hnp.arrays(np.float32, (8, 128), elements=floats),
       g=hnp.arrays(np.float32, (8, 128), elements=floats),
       hh=hnp.arrays(np.float32, (8, 128), elements=pos_floats),
       lr=st.floats(min_value=7.62939453125e-06, max_value=0.125, width=32),
       do_h=st.sampled_from([0.0, 1.0]))
def test_kernel_equals_oracle_property(theta, g, hh, lr, do_h):
    """Pallas kernel == oracle for arbitrary inputs (the per-kernel
    allclose requirement, driven by hypothesis)."""
    m = 0.1 * g
    h = 0.5 * hh
    hp = dict(beta1=0.9, beta2=0.95, rho=0.04, eps=1e-12, weight_decay=1e-4)
    out = sophia_update_flat(jnp.asarray(theta), jnp.asarray(m),
                             jnp.asarray(h), jnp.asarray(g),
                             jnp.asarray(hh), do_h, lr, interpret=True, **hp)
    ref = sophia_update_ref(theta, m, h, g, hh, do_h, lr=lr, **hp)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(theta=hnp.arrays(np.float32, (4, 16), elements=floats),
       lr=st.floats(min_value=7.62939453125e-06, max_value=0.125, width=32),
       rho=st.floats(min_value=0.0009765625, max_value=1.0, width=32))
def test_update_bounded_step_property(theta, lr, rho):
    """Paper's guarantee: per-coordinate move (beyond weight decay) is
    bounded by lr * rho regardless of gradient/Hessian values."""
    key = jax.random.PRNGKey(0)
    m = 100.0 * jax.random.normal(key, theta.shape)
    h = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), theta.shape))
    out = sophia.apply_update({"t": jnp.asarray(theta)}, {"t": m}, {"t": h},
                              lr=lr, rho=rho, eps=1e-12, weight_decay=0.0)
    delta = np.abs(np.asarray(out["t"]) - theta)
    # allow one ulp of theta for the float32 subtract
    assert np.all(delta <= lr * rho * (1 + 1e-5) + 1e-5 * np.abs(theta) + 1e-6)


# --------------------------- compressor round-trip invariants
#
# Random geometries / seeds / dtypes / lowering paths, asserting the
# algebraic contracts every stream compressor must keep: dequant
# values live on the quantization lattice, EF residuals reconstruct
# the delta exactly, sparsifier/sign codebooks are what the wire
# format claims — and the client-batched entry points agree with the
# per-client ones.


@settings(**SETTINGS)
@given(geom=st.sampled_from(GEOMETRIES),
       seed=st.integers(0, 2 ** 31 - 1),
       bits=st.sampled_from([8, 4]),
       use_pallas=st.booleans(),
       dtype=st.sampled_from([np.float32, "bfloat16"]))
def test_quant_dequant_lattice_invariant(geom, seed, bits, use_pallas,
                                         dtype):
    """int8/int4 reconstructions are integral multiples of the per-row
    scale, with |code| <= qmax — for both lowering paths and both
    storage dtypes."""
    total, cols = geom
    comp = _make(f"int{bits}", total, cols, use_pallas)
    key = jax.random.PRNGKey(seed)
    flat = jax.random.normal(jax.random.fold_in(key, 1),
                             (comp.spec.rows, comp.spec.cols)
                             ).astype(jnp.dtype(dtype))
    xhat, _ = comp.roundtrip(key, flat)
    scale = np.asarray(comp._scales(flat), np.float32)
    safe = np.where(scale > 0, scale, 1.0)
    q = np.asarray(xhat, np.float32) / safe
    # a bf16 store rounds the reconstruction off the exact lattice by
    # up to one bf16 ulp of the code magnitude; fp32 is exact
    ulp = 2.0 ** -8 if dtype == "bfloat16" else 0.0
    assert np.all(np.abs(q - np.round(q)) <= ulp * np.abs(q) + 1e-3)
    assert np.all(np.abs(q) <= comp.qmax * (1 + ulp) + 1e-3)


@settings(**SETTINGS)
@given(geom=st.sampled_from(GEOMETRIES),
       seed=st.integers(0, 2 ** 31 - 1),
       use_pallas=st.booleans())
def test_uplink_ef_residual_reconstructs_delta(geom, seed, use_pallas):
    """EF invariant: xhat + new_ef == (theta - start) + ef, so nothing
    the quantizer drops is ever lost (the residual carries it)."""
    total, cols = geom
    comp = _make("int8", total, cols, use_pallas, error_feedback=True)
    key = jax.random.PRNGKey(seed)
    shape = (comp.spec.rows, comp.spec.cols)
    theta = jax.random.normal(jax.random.fold_in(key, 1), shape)
    start = theta + 0.05 * jax.random.normal(jax.random.fold_in(key, 2),
                                             shape)
    ef = 0.01 * jax.random.normal(jax.random.fold_in(key, 3), shape)
    xhat, _, new_ef = comp.encode_delta(key, theta, start, ef)
    delta = np.asarray(theta - start + ef)
    np.testing.assert_allclose(np.asarray(xhat) + np.asarray(new_ef),
                               delta, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(geom=st.sampled_from(GEOMETRIES),
       seed=st.integers(0, 2 ** 31 - 1),
       use_pallas=st.booleans(),
       ratio=st.sampled_from([0.01, 0.1, 0.5]))
def test_topk_sparsity_and_value_preservation(geom, seed, use_pallas,
                                              ratio):
    """top-k keeps at most k coordinates and passes their values
    through untouched (zero elsewhere)."""
    total, cols = geom
    comp = _make("topk", total, cols, use_pallas, topk_ratio=ratio)
    key = jax.random.PRNGKey(seed)
    flat = jax.random.normal(jax.random.fold_in(key, 1),
                             (comp.spec.rows, comp.spec.cols))
    xhat, _ = comp.roundtrip(key, flat)
    xh = np.asarray(xhat)
    nz = xh != 0
    assert nz.sum() <= comp.k
    np.testing.assert_array_equal(xh[nz], np.asarray(flat)[nz])


@settings(**SETTINGS)
@given(geom=st.sampled_from(GEOMETRIES),
       seed=st.integers(0, 2 ** 31 - 1),
       use_pallas=st.booleans())
def test_signsgd_codebook(geom, seed, use_pallas):
    """signsgd reconstructions take exactly the values {-s, 0, +s}
    with s the reported aggregation stat (mean |x|)."""
    total, cols = geom
    comp = _make("signsgd", total, cols, use_pallas)
    key = jax.random.PRNGKey(seed)
    flat = jax.random.normal(jax.random.fold_in(key, 1),
                             (comp.spec.rows, comp.spec.cols))
    xhat, stat = comp.roundtrip(key, flat)
    s = np.float32(stat)
    xh = np.asarray(xhat)
    assert np.all(np.isin(xh, [-s, np.float32(0.0), s]))
    np.testing.assert_allclose(s, np.abs(np.asarray(flat)).sum()
                               / comp.spec.total, rtol=1e-6)


@settings(**SETTINGS)
@given(geom=st.sampled_from(GEOMETRIES),
       seed=st.integers(0, 2 ** 31 - 1),
       compressor=st.sampled_from(["int8", "int4", "topk", "signsgd"]),
       use_pallas=st.booleans())
def test_roundtrip_batched_matches_unbatched(geom, seed, compressor,
                                             use_pallas):
    """`roundtrip_batched` over an (N, rows, cols) stack == the N
    per-client round-trips, for every compressor family and both
    lowering paths (the Pallas path is ONE client-batched launch)."""
    total, cols = geom
    n = 3
    comp = _make(compressor, total, cols, use_pallas)
    keys = jax.random.split(jax.random.PRNGKey(seed), n)
    stack = jax.random.normal(jax.random.fold_in(keys[0], 99),
                              (n, comp.spec.rows, comp.spec.cols))
    bx, bs = comp.roundtrip_batched(keys, stack)
    for i in range(n):
        xi, si = comp.roundtrip(keys[i], stack[i])
        np.testing.assert_allclose(np.asarray(bx[i]), np.asarray(xi),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(bs[i]), np.asarray(si),
                                   rtol=1e-6, atol=0)


@settings(**SETTINGS)
@given(geom=st.sampled_from(GEOMETRIES),
       seed=st.integers(0, 2 ** 31 - 1),
       use_pallas=st.booleans(),
       with_ef=st.booleans(),
       shared_start=st.booleans())
def test_encode_delta_batched_matches_unbatched(geom, seed, use_pallas,
                                                with_ef, shared_start):
    """`encode_delta_batched` == the per-client uplink encodes, for a
    shared 2D start (replicas off) and per-client start stacks, with
    and without EF — and the EF invariant holds row by row."""
    total, cols = geom
    n = 3
    comp = _make("int8", total, cols, use_pallas,
                 error_feedback=with_ef)
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, n)
    shape3 = (n, comp.spec.rows, comp.spec.cols)
    theta = jax.random.normal(jax.random.fold_in(key, 1), shape3)
    start = (jax.random.normal(jax.random.fold_in(key, 2),
                               shape3[1:]) if shared_start
             else jax.random.normal(jax.random.fold_in(key, 2), shape3))
    ef = (0.01 * jax.random.normal(jax.random.fold_in(key, 3), shape3)
          if with_ef else None)
    bx, bs, bef = comp.encode_delta_batched(keys, theta, start, ef)
    assert (bef is None) == (ef is None)
    for i in range(n):
        si = start if shared_start else start[i]
        xi, _, efi = comp.encode_delta(keys[i], theta[i], si,
                                       None if ef is None else ef[i])
        np.testing.assert_allclose(np.asarray(bx[i]), np.asarray(xi),
                                   rtol=1e-6, atol=1e-7)
        if ef is not None:
            np.testing.assert_allclose(np.asarray(bef[i]),
                                       np.asarray(efi),
                                       rtol=1e-6, atol=1e-7)
            delta = np.asarray(theta[i] - si + ef[i])
            np.testing.assert_allclose(
                np.asarray(bx[i]) + np.asarray(bef[i]), delta,
                rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(vals=hnp.arrays(np.float32, (3, 5, 7), elements=floats))
def test_aggregation_mean_bounds(vals):
    """Server aggregate lies in the per-coordinate convex hull of client
    params (Eq. 4 sanity)."""
    from repro.utils.tree import tree_mean_axis0
    agg = np.asarray(tree_mean_axis0({"w": jnp.asarray(vals)})["w"])
    assert np.all(agg <= vals.max(axis=0) + 1e-5)
    assert np.all(agg >= vals.min(axis=0) - 1e-5)


# --------------------------- robust aggregation invariants
#
# The adversarial-fleet contracts of docs/robustness.md, driven by
# hypothesis: trimmed means stay inside the survivor hull, the
# coordinate median ignores arrival order, norm clipping is a no-op
# on in-ball stacks, and wire attacks never change wire geometry.

#: (K, rows, cols) robust-stack geometry pool — like GEOMETRIES, each
#: shape is one jit compile so the values/seeds do the roaming
ROBUST_GEOMETRIES = [(5, 4, 8), (9, 3, 16), (4, 7, 5)]


@settings(**SETTINGS)
@given(geom=st.sampled_from(ROBUST_GEOMETRIES),
       seed=st.integers(0, 2 ** 31 - 1),
       trim=st.integers(0, 2))
def test_trimmed_mean_within_survivor_hull(geom, seed, trim):
    """The trimmed mean lies per-coordinate inside [min, max] of the
    sorted-interior survivors, for any weights > 0."""
    from repro.kernels.ref import robust_agg_ref
    K, R, C = geom
    trim = min(trim, (K - 1) // 2)
    key = jax.random.PRNGKey(seed)
    wires = 10.0 * jax.random.normal(jax.random.fold_in(key, 1),
                                     (K, R, C))
    w = jax.random.uniform(jax.random.fold_in(key, 2), (K,),
                           minval=0.1, maxval=2.0)
    out = np.asarray(robust_agg_ref(wires, w, jnp.ones((K,)),
                                    trim=trim, normalize=True))
    srt = np.sort(np.asarray(wires), axis=0)[trim:K - trim]
    assert np.all(out >= srt.min(axis=0) - 1e-4)
    assert np.all(out <= srt.max(axis=0) + 1e-4)


@settings(**SETTINGS)
@given(geom=st.sampled_from(ROBUST_GEOMETRIES),
       seed=st.integers(0, 2 ** 31 - 1))
def test_coordinate_median_permutation_invariant(geom, seed):
    """Shuffling the arrival axis leaves the coordinate median
    unchanged (uniform weights; ties broken by value, not index)."""
    from repro.configs.base import RobustConfig
    from repro.robust import aggregators as ragg
    K, R, C = geom
    key = jax.random.PRNGKey(seed)
    wires = jax.random.normal(jax.random.fold_in(key, 1), (K, R, C))
    perm = jax.random.permutation(jax.random.fold_in(key, 2), K)
    rb = RobustConfig(aggregator="coordinate_median")
    ones = jnp.ones((K,), jnp.float32)
    a = ragg.aggregate_stack(rb, wires, ones)
    b = ragg.aggregate_stack(rb, wires[perm], ones)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(geom=st.sampled_from(ROBUST_GEOMETRIES),
       seed=st.integers(0, 2 ** 31 - 1),
       clip=st.floats(min_value=0.5, max_value=50.0, width=32))
def test_norm_clip_idempotent_on_in_ball_stacks(geom, seed, clip):
    """Clipping a stack whose arrivals are already inside the norm
    ball is a bitwise no-op (scale factor exactly 1.0), and clipped
    outputs never exceed the ball."""
    from repro.robust import aggregators as ragg
    K, R, C = geom
    key = jax.random.PRNGKey(seed)
    raw = jax.random.normal(jax.random.fold_in(key, 1), (K, R, C))
    nrm = jnp.sqrt(jnp.sum(raw * raw, axis=(1, 2), keepdims=True))
    inside = raw * (0.999 * clip / jnp.maximum(nrm, 1e-30))
    s = np.asarray(ragg.clip_scales(inside, jnp.float32(clip)))
    np.testing.assert_array_equal(s, np.ones_like(s))
    s_out = np.asarray(ragg.clip_scales(10.0 * raw, jnp.float32(clip)))
    scaled = np.asarray(10.0 * raw) * s_out[:, None, None]
    norms = np.sqrt((scaled ** 2).sum(axis=(1, 2)))
    assert np.all(norms <= clip * (1 + 1e-5))


@settings(**SETTINGS)
@given(geom=st.sampled_from(ROBUST_GEOMETRIES),
       seed=st.integers(0, 2 ** 31 - 1),
       attack=st.sampled_from(["sign_flip", "scale", "random_wire"]),
       frac=st.sampled_from([0.0, 0.25, 0.5, 1.0]))
def test_attacks_preserve_wire_geometry_property(geom, seed, attack,
                                                 frac):
    """Wire attacks keep the packed stack's shape and dtype and leave
    benign rows bitwise untouched for any mask."""
    from repro.configs.base import RobustConfig
    from repro.robust import attacks as ratt
    K, R, C = geom
    rb = RobustConfig(attack=attack, attack_fraction=frac,
                      seed=seed % 1000)
    mask = jnp.asarray(ratt.byzantine_mask(rb, K))
    wires = jax.random.normal(jax.random.PRNGKey(seed), (K, R, C))
    out = ratt.attack_wires(rb, wires, mask,
                            jax.random.PRNGKey(seed + 1))
    assert out.shape == wires.shape and out.dtype == wires.dtype
    m = np.asarray(mask)
    np.testing.assert_array_equal(np.asarray(out)[~m],
                                  np.asarray(wires)[~m])
    assert int(m.sum()) == int(round(frac * K))
