"""repro.sched tests: latency-model determinism, sync delegation,
semisync degeneracy (bit-identical to the synchronous comm path),
virtual-clock determinism, async staleness semantics, and the fused
staleness-weighted accumulate kernel."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CommConfig, FedConfig, SchedConfig
from repro.core.fed import FedEngine
from repro.data import synthetic as syn
from repro.models.small import MLPTask
from repro.sched import (VirtualScheduler, client_multipliers,
                         dispatch_seconds)


# -------------------------------------------------------- latency model
def test_latency_multipliers_deterministic_and_profiled():
    s = SchedConfig(latency_profile="straggler", straggler_frac=0.25,
                    straggler_slowdown=10.0, seed=3)
    m1 = client_multipliers(s, 8)
    m2 = client_multipliers(s, 8)
    np.testing.assert_array_equal(m1, m2)
    assert int(np.sum(m1 == 10.0)) == 2 and int(np.sum(m1 == 1.0)) == 6
    m3 = client_multipliers(dataclasses.replace(s, seed=4), 8)
    assert not np.array_equal(m1, m3)
    uni = client_multipliers(SchedConfig(), 8)
    np.testing.assert_array_equal(uni, np.ones(8))
    logn = client_multipliers(
        SchedConfig(latency_profile="lognormal", seed=1), 64)
    assert logn.std() > 0
    with pytest.raises(ValueError):
        client_multipliers(SchedConfig(latency_profile="bogus"), 4)


def test_dispatch_seconds_charges_compression():
    """Compressed uplinks shorten the simulated round, not just the
    reported bytes."""
    fed_id = FedConfig(num_clients=4, local_iters=2)
    fed_int8 = dataclasses.replace(fed_id,
                                   comm=CommConfig(compressor="int8"))
    t_id = dispatch_seconds(fed_id, 100_000, 4)
    t_int8 = dispatch_seconds(fed_int8, 100_000, 4)
    assert np.all(t_int8 < t_id)


# ------------------------------------------------------ engine fixtures
@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    x, y = syn.make_image_data(key, 1024, "mnist", noise=1.0)
    part = syn.dirichlet_partition(jax.random.PRNGKey(1), y, 4, alpha=0.5)
    tr, _ = syn.train_test_split(part)
    task = MLPTask(hidden=32)

    def batch_fn(v):
        return syn.client_batches(jax.random.fold_in(key, 100 + v),
                                  x, y, tr, 32)

    return task, batch_fn


def _fed(**kw):
    base = dict(num_clients=4, local_iters=2, optimizer="fed_sophia",
                lr=0.01, tau=2)
    base.update(kw)
    return FedConfig(**base)


RUN_RNG = jax.random.PRNGKey(7)


def _run_sched(task, fed, batch_fn, events, seed=2):
    eng = FedEngine(task, fed)
    sched = VirtualScheduler(eng, batch_fn)
    state = eng.init(jax.random.PRNGKey(seed))
    return sched.run(state, events, RUN_RNG)


# ------------------------------------------------------- sync delegation
def test_sync_discipline_bit_identical_to_engine(setup):
    """--schedule sync is the existing engine, bitwise: the scheduler
    delegates every event to FedEngine.round verbatim."""
    task, batch_fn = setup
    fed = _fed(comm=CommConfig(compressor="int8"))
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(2))
    rf = jax.jit(eng.round)
    for v in range(3):
        state, _ = rf(state, batch_fn(v), jax.random.fold_in(RUN_RNG, v))
    s_sched, trace = _run_sched(task, fed, batch_fn, 3)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(s_sched)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [e.version for e in trace.events] == [1, 2, 3]
    # uniform latencies: every round costs the same virtual time
    dts = np.diff([0.0] + [e.time for e in trace.events])
    np.testing.assert_allclose(dts, dts[0])


# ------------------------------------------------- semisync degeneracy
@pytest.mark.parametrize("comm", [
    CommConfig(compressor="int8"),
    CommConfig(compressor="int8", downlink_compressor="int8"),
    CommConfig(compressor="topk", topk_ratio=0.05),
], ids=["uplink-int8", "bidir-int8", "topk-ef"])
def test_semisync_full_buffer_uniform_is_sync(setup, comm):
    """Degeneracy acceptance: semisync with buffer_size == num_clients
    and uniform latencies is BIT-IDENTICAL to the synchronous comm
    path — state dict equal leaf-for-leaf after 3 aggregations."""
    task, batch_fn = setup
    fed_sync = _fed(comm=comm)
    fed_semi = dataclasses.replace(
        fed_sync, sched=SchedConfig(discipline="semisync", buffer_size=4))
    s_sync, tr_sync = _run_sched(task, fed_sync, batch_fn, 3)
    s_semi, tr_semi = _run_sched(task, fed_semi, batch_fn, 3)
    assert sorted(s_sync.keys()) == sorted(s_semi.keys())
    for a, b in zip(jax.tree.leaves(s_sync), jax.tree.leaves(s_semi)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # same virtual cost and same bytes on the wire, event for event
    assert [e.time for e in tr_sync.events] == \
        [e.time for e in tr_semi.events]
    assert [e.cum_bytes for e in tr_sync.events] == \
        [e.cum_bytes for e in tr_semi.events]
    assert all(e.staleness == (0,) * 4 for e in tr_semi.events)


# --------------------------------------------- virtual-clock determinism
def test_virtual_clock_deterministic(setup):
    """Two runs under one seed produce the same event log, tick for
    tick (times, arrival order, staleness, weights, bytes)."""
    task, batch_fn = setup
    fed = _fed(comm=CommConfig(compressor="int8"),
               sched=SchedConfig(discipline="semisync", buffer_size=2,
                                 latency_profile="lognormal", seed=5))
    _, t1 = _run_sched(task, fed, batch_fn, 4)
    _, t2 = _run_sched(task, fed, batch_fn, 4)
    assert t1.events == t2.events
    assert all(b.time >= a.time
               for a, b in zip(t1.events, t1.events[1:]))
    # a different latency seed reshuffles the arrival order/times
    fed3 = dataclasses.replace(
        fed, sched=dataclasses.replace(fed.sched, seed=6))
    _, t3 = _run_sched(task, fed3, batch_fn, 4)
    assert [e.time for e in t3.events] != [e.time for e in t1.events]


# ------------------------------------------------------- semisync rounds
def test_semisync_straggler_faster_and_stale(setup):
    """Under a straggler profile the buffered rounds exclude the slow
    client early (its delta arrives late, stale); virtual time per
    aggregation is far below sync's straggler-dominated rounds."""
    task, batch_fn = setup
    prof = dict(latency_profile="straggler", straggler_frac=0.25,
                straggler_slowdown=10.0)
    fed_sync = _fed(comm=CommConfig(compressor="int8"),
                    sched=SchedConfig(**prof))
    fed_semi = dataclasses.replace(
        fed_sync, sched=SchedConfig(discipline="semisync",
                                    buffer_size=2, **prof))
    _, tr_sync = _run_sched(task, fed_sync, batch_fn, 3)
    s_semi, tr_semi = _run_sched(task, fed_semi, batch_fn, 3)
    assert tr_semi.final_time < tr_sync.final_time
    slow = int(np.argmax(client_multipliers(fed_semi.sched, 4)))
    assert all(slow not in e.clients for e in tr_semi.events[:2])
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree.leaves(s_semi["params"]))


def test_semisync_buffer_validation(setup):
    task, batch_fn = setup
    fed = _fed(sched=SchedConfig(discipline="semisync", buffer_size=9))
    with pytest.raises(ValueError):
        VirtualScheduler(FedEngine(task, fed), batch_fn)
    fed = _fed(sched=SchedConfig(discipline="nowait"))
    with pytest.raises(ValueError):
        VirtualScheduler(FedEngine(task, fed), batch_fn)
    fed = _fed(comm=CommConfig(hessian_compressor="int4"),
               sched=SchedConfig(discipline="async"))
    with pytest.raises(ValueError):
        VirtualScheduler(FedEngine(task, fed), batch_fn)


# ---------------------------------------------------------------- async
def test_async_staleness_weights_and_versions(setup):
    """Async applies one arrival per event; staleness grows with the
    model versions applied since dispatch and the weight follows
    (1+tau)^-p exactly."""
    task, batch_fn = setup
    fed = _fed(comm=CommConfig(compressor="int8"),
               sched=SchedConfig(discipline="async", staleness_power=0.5,
                                 latency_profile="straggler",
                                 straggler_frac=0.25,
                                 straggler_slowdown=3.0))
    s, trace = _run_sched(task, fed, batch_fn, 8)
    assert [e.version for e in trace.events] == list(range(1, 9))
    for e in trace.events:
        assert len(e.clients) == 1
        tau = e.staleness[0]
        assert e.weights[0] == pytest.approx((1.0 + tau) ** -0.5)
    # the straggler eventually delivers a genuinely stale update
    assert max(e.staleness[0] for e in trace.events) >= 1
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree.leaves(s["params"]))


def test_async_pallas_matches_reference(setup):
    """The fused staleness-accumulate kernel path produces the same
    schedule as the pure-JAX aggregation (allclose; same noise)."""
    task, batch_fn = setup
    base = _fed(comm=CommConfig(compressor="int8"),
                sched=SchedConfig(discipline="async",
                                  latency_profile="lognormal", seed=3))
    s_ref, t_ref = _run_sched(task, base, batch_fn, 5)
    fed_pal = dataclasses.replace(
        base, comm=dataclasses.replace(base.comm, use_pallas=True))
    s_pal, t_pal = _run_sched(task, fed_pal, batch_fn, 5)
    assert [e.time for e in t_ref.events] == [e.time for e in t_pal.events]
    for a, b in zip(jax.tree.leaves(s_ref["params"]),
                    jax.tree.leaves(s_pal["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ----------------------------------------------------- accumulate kernel
def test_stale_accum_kernel_matches_ref():
    from repro.kernels.ref import stale_accum_ref
    from repro.kernels.stale_accum import stale_accum_flat
    key = jax.random.PRNGKey(0)
    wires = jax.random.normal(key, (5, 300, 130))
    weights = jnp.asarray([1.0, 0.5, 0.25, 1.0, 0.7])
    for inv in (1.0, float(1.0 / jnp.sum(weights))):
        a = stale_accum_flat(wires, weights, inv, interpret=True)
        b = stale_accum_ref(wires, weights, inv)
        # sequential in-VMEM accumulation vs jnp.sum's pairwise tree:
        # same math, different fp summation order
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    one = stale_accum_flat(wires[:1], weights[:1], 1.0, interpret=True)
    np.testing.assert_allclose(np.asarray(one), np.asarray(wires[0]),
                               rtol=1e-6, atol=1e-7)
