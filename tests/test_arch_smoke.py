"""Per-assigned-architecture smoke tests (deliverable f).

Each instantiates a REDUCED variant of the same family (2 layers,
d_model<=512, <=4 experts) and runs one forward + one federated
Fed-Sophia training round on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.base import FedConfig
from repro.core.fed import FedEngine
from repro.models import transformer as T

# full 12-arch sweep x (forward, fed round, decode) — the single
# largest tier-1 cost; run explicitly with `pytest -m slow`
pytestmark = pytest.mark.slow

ARCHS = configs.ARCH_IDS


def _reduced(arch_id):
    return configs.get_model_config(arch_id).reduced(d_model=128)


def _batch(cfg, C, b, S, key):
    if cfg.embedding_inputs:
        batch = {"embeds": jax.random.normal(key, (C, b, S, cfg.d_model))}
    else:
        batch = {"tokens": jax.random.randint(key, (C, b, S), 0,
                                              cfg.vocab_size)}
    batch["labels"] = jax.random.randint(
        jax.random.fold_in(key, 1), (C, b, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_no_nan(arch):
    cfg = _reduced(arch)
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    B, S = 2, 16
    batch = jax.tree.map(lambda x: x[0], _batch(cfg, 1, B, S, key))
    logits, _, aux = T.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_fed_sophia_round(arch):
    cfg = _reduced(arch)
    task = T.LMTask(cfg)
    overrides = configs.get_fed_overrides(arch)
    fed = FedConfig(num_clients=2, local_iters=2, optimizer="fed_sophia",
                    lr=1e-3, tau=2,
                    strategy=overrides.get("strategy", "parallel"),
                    schedule=overrides.get("schedule", "const"))
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, 2, 2, 16, jax.random.PRNGKey(2))
    state, metrics = jax.jit(eng.round)(state, batch, jax.random.PRNGKey(3))
    assert bool(jnp.isfinite(metrics["loss"])), arch
    assert not any(bool(jnp.any(jnp.isnan(l)))
                   for l in jax.tree.leaves(state["params"])
                   if jnp.issubdtype(l.dtype, jnp.floating)), arch


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert-xlarge"])
def test_reduced_decode_step(arch):
    cfg = _reduced(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    B = 2
    cache = T.init_cache(cfg, B, 32)
    if cfg.embedding_inputs:
        batch = {"embeds": jax.random.normal(key, (B, 1, cfg.d_model))}
    else:
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, new_cache = jax.jit(
        lambda p, b, c: T.decode_step(p, cfg, b, c, jnp.asarray(5, jnp.int32))
    )(params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)
