"""Wire-format golden tests.

Freeze every stream's serialized payload layout and exact byte counts
against the committed fixture (`tests/golden/wire_format.json`), so
byte accounting stays honest as compressors evolve.  The normative
spec is docs/wire-format.md — change spec, fixture, and serializers
together or not at all.

Regenerate (only on a deliberate spec change):

    PYTHONPATH=src python tests/test_wire_golden.py --regen
"""
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.comm import accounting, flat as cflat
from repro.comm.compressors import make_stream_compressor
from repro.configs.base import CommConfig

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "wire_format.json")
QUANT_BLOCK = 128
ENCODE_KEY = 99


def _input_tree():
    """Deterministic fixed input (threefry is stable across platforms)."""
    key = jax.random.PRNGKey(1234)
    return {"b": jax.random.normal(jax.random.fold_in(key, 1), (300,)),
            "w": jax.random.normal(key, (48, 25))}


def _cases():
    """(case-name, stream, CommConfig, input transform) per pinned payload.

    Every compressor is pinned on the uplink; the downlink and hessian
    streams are pinned through their own config fields to prove the
    per-stream resolution (`CommConfig.stream`) reaches the same
    layouts — including the per-stream packing-geometry overrides
    (`*-coarse` cases: the stream packs with its own quant_block /
    topk_ratio).  The hessian input is squared — curvature is
    nonnegative.
    """
    cases = []
    for name in ("identity", "int8", "int4", "topk", "signsgd"):
        cases.append((f"uplink/{name}", "uplink",
                      CommConfig(compressor=name, topk_ratio=0.02,
                                 quant_block=QUANT_BLOCK),
                      lambda x: x))
    cases.append(("downlink/int8", "downlink",
                  CommConfig(downlink_compressor="int8",
                             quant_block=QUANT_BLOCK), lambda x: x))
    cases.append(("downlink/topk", "downlink",
                  CommConfig(downlink_compressor="topk", topk_ratio=0.02,
                             quant_block=QUANT_BLOCK), lambda x: x))
    cases.append(("downlink/topk-coarse", "downlink",
                  CommConfig(downlink_compressor="topk", topk_ratio=0.02,
                             downlink_topk_ratio=0.05,
                             quant_block=QUANT_BLOCK), lambda x: x))
    cases.append(("hessian/int4", "hessian",
                  CommConfig(hessian_compressor="int4",
                             quant_block=QUANT_BLOCK),
                  lambda x: x * x))
    cases.append(("hessian/int8", "hessian",
                  CommConfig(hessian_compressor="int8",
                             quant_block=QUANT_BLOCK),
                  lambda x: x * x))
    cases.append(("hessian/int4-coarse", "hessian",
                  CommConfig(hessian_compressor="int4",
                             quant_block=QUANT_BLOCK,
                             hessian_quant_block=4 * QUANT_BLOCK),
                  lambda x: x * x))
    return cases


def _payload_record(stream, comm, transform):
    tree = _input_tree()
    view = comm.stream(stream)
    # each stream packs with its OWN quant_block (geometry overrides)
    spec = cflat.flat_spec(tree, cols=view.quant_block)
    flat = transform(cflat.pack(tree, spec))
    comp = make_stream_compressor(comm, stream, spec)
    raw = comp.serialize(comp.encode(jax.random.PRNGKey(ENCODE_KEY), flat))
    header = cflat.Header.unpack(raw)
    assert header == comp.header()
    return {
        "stream": stream,
        "compressor": view.compressor,
        "total": spec.total,
        "quant_block": view.quant_block,
        "bytes": len(raw),
        "sha256": hashlib.sha256(raw).hexdigest(),
        "header_hex": raw[:cflat.HEADER_BYTES].hex(),
        "head_hex": raw[cflat.HEADER_BYTES:cflat.HEADER_BYTES + 24].hex(),
    }


def _round_totals_record():
    """Exact per-round per-stream integers for the bidirectional regime
    (the numbers `benchmarks/run.py --only comm` is built on)."""
    comm = CommConfig(compressor="int8", downlink_compressor="int8",
                      hessian_compressor="int4", participation=0.5)
    return {"n_params": 100_000, "num_clients": 8,
            **accounting.round_bytes(comm, 100_000, 8)}


def _generate():
    return {
        "spec": "docs/wire-format.md",
        "payloads": {name: _payload_record(stream, comm, tf)
                     for name, stream, comm, tf in _cases()},
        "round_totals/bidir": _round_totals_record(),
    }


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.mark.parametrize("name,stream,comm,tf",
                         _cases(), ids=[c[0] for c in _cases()])
def test_payload_matches_golden(golden, name, stream, comm, tf):
    got = _payload_record(stream, comm, tf)
    assert got == golden["payloads"][name], (
        f"{name}: serialized payload diverged from the committed wire "
        f"format — if docs/wire-format.md changed on purpose, "
        f"regenerate with `python tests/test_wire_golden.py --regen`")


@pytest.mark.parametrize("name,stream,comm,tf",
                         _cases(), ids=[c[0] for c in _cases()])
def test_serialized_length_equals_accounting(name, stream, comm, tf):
    """len(serialize(...)) == accounting.wire_bytes, every stream."""
    got = _payload_record(stream, comm, tf)
    assert got["bytes"] == accounting.wire_bytes(
        comm.stream(stream), got["total"])


def test_round_totals_match_golden(golden):
    assert _round_totals_record() == golden["round_totals/bidir"]


def test_round_totals_consistency():
    """round_bytes composes stream_bytes exactly (S uplinks/downlinks,
    ONE common curvature broadcast) and total sums every stream."""
    comm = CommConfig(compressor="int4", downlink_compressor="int8",
                      hessian_compressor="int4", participation=0.5)
    n, C = 54_321, 10
    rb = accounting.round_bytes(comm, n, C)
    s = rb["participants"]
    assert rb["uplink_bytes"] == s * accounting.stream_bytes(
        comm, "uplink", n)
    assert rb["downlink_bytes"] == s * accounting.stream_bytes(
        comm, "downlink", n)
    assert rb["hessian_uplink_bytes"] == s * accounting.stream_bytes(
        comm, "hessian", n)
    assert rb["hessian_downlink_bytes"] == accounting.stream_bytes(
        comm, "hessian", n)
    assert rb["total_bytes"] == (rb["uplink_bytes"] + rb["downlink_bytes"]
                                 + rb["hessian_uplink_bytes"]
                                 + rb["hessian_downlink_bytes"])


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="rewrite the committed golden fixture")
    if ap.parse_args().regen:
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            json.dump(_generate(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN}")
