"""Adversarial-fleet tests (docs/robustness.md).

The load-bearing suite is the DEGENERACY harness: robust aggregators
with zero adversaries and a zero trim/clip must be **bitwise**
identical to the weighted-mean path — across {sync, semisync, async}
disciplines and {direct, uplink-int8, bidirectional} comm regimes —
because `repro.robust.aggregators.resolve` maps degenerate
parameterizations to ``"mean"`` at trace time and the caller keeps its
existing traced graph.  Alongside: kernel-vs-reference conformance at
fp32/bf16/fp8, attack-transform geometry, deterministic fault masks,
and a small end-to-end recovery check (robust aggregation beats plain
mean under sign-flip byzantine clients).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (AGGREGATORS, CommConfig, FedConfig,
                                RobustConfig, SchedConfig)
from repro.core.fed import FedEngine
from repro.data import synthetic as syn
from repro.kernels.ref import robust_agg_ref
from repro.kernels.robust_agg import robust_agg_flat
from repro.models.small import MLPTask
from repro.robust import (aggregators as ragg, attacks as ratt)
from repro.sched import SchedTrace, VirtualScheduler

RUN_RNG = jax.random.PRNGKey(7)

#: every degenerate parameterization resolves to "mean" — same traced
#: graph as the default, hence bitwise (docs/robustness.md)
DEGENERATE = [
    pytest.param(RobustConfig(aggregator="trimmed_mean",
                              trim_fraction=0.0), id="trim0"),
    pytest.param(RobustConfig(aggregator="norm_clip", clip_norm=0.0),
                 id="clip0"),
    pytest.param(RobustConfig(attack="sign_flip", attack_fraction=0.0),
                 id="attack-frac0"),
]

COMM_REGIMES = [
    pytest.param(CommConfig(), id="direct"),
    pytest.param(CommConfig(compressor="int8"), id="uplink-int8"),
    pytest.param(CommConfig(compressor="int8",
                            downlink_compressor="int8"), id="bidir"),
]


# ------------------------------------------------------ engine fixtures
@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    x, y = syn.make_image_data(key, 1024, "mnist", noise=1.0)
    part = syn.dirichlet_partition(jax.random.PRNGKey(1), y, 4, alpha=0.5)
    tr, _ = syn.train_test_split(part)
    task = MLPTask(hidden=32)

    def batch_fn(v):
        return syn.client_batches(jax.random.fold_in(key, 100 + v),
                                  x, y, tr, 32)

    return task, batch_fn


def _fed(**kw):
    base = dict(num_clients=4, local_iters=2, optimizer="fed_sophia",
                lr=0.01, tau=2)
    base.update(kw)
    return FedConfig(**base)


def _run_engine(task, fed, batch_fn, rounds=2):
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(2))
    rf = eng.round_fn(donate=False)
    for v in range(rounds):
        state, m = rf(state, batch_fn(v), jax.random.fold_in(RUN_RNG, v))
    return state, m


def _run_sched(task, fed, batch_fn, events):
    eng = FedEngine(task, fed)
    sched = VirtualScheduler(eng, batch_fn)
    state = eng.init(jax.random.PRNGKey(2))
    return sched.run(state, events, RUN_RNG)


def _assert_states_equal(s0, s1):
    for a, b in zip(jax.tree.leaves(s0), jax.tree.leaves(s1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- degeneracy: engine
@pytest.mark.parametrize("comm", COMM_REGIMES)
@pytest.mark.parametrize("robust", DEGENERATE)
def test_engine_degenerate_robust_is_bitwise_mean(setup, comm, robust):
    """A degenerate RobustConfig keeps the engine round BITWISE equal
    to the default weighted-mean path, per comm regime."""
    task, batch_fn = setup
    fed = _fed(comm=comm)
    s0, _ = _run_engine(task, fed, batch_fn)
    s1, _ = _run_engine(task, dataclasses.replace(fed, robust=robust),
                        batch_fn)
    _assert_states_equal(s0, s1)


@pytest.mark.parametrize("robust", DEGENERATE)
def test_engine_sequential_degenerate_bitwise(setup, robust):
    """The sequential (scan) strategy keeps the same contract."""
    task, batch_fn = setup
    fed = _fed(strategy="sequential", comm=CommConfig(compressor="int8"))
    s0, _ = _run_engine(task, fed, batch_fn)
    s1, _ = _run_engine(task, dataclasses.replace(fed, robust=robust),
                        batch_fn)
    _assert_states_equal(s0, s1)


# ---------------------------------------------- degeneracy: scheduler
@pytest.mark.parametrize("sched", [
    pytest.param(SchedConfig(), id="sync"),
    pytest.param(SchedConfig(discipline="semisync", buffer_size=2,
                             latency_profile="lognormal", seed=5),
                 id="semisync"),
    pytest.param(SchedConfig(discipline="async",
                             latency_profile="lognormal", seed=5),
                 id="async"),
])
@pytest.mark.parametrize("robust", DEGENERATE)
def test_sched_degenerate_robust_is_bitwise_mean(setup, sched, robust):
    """Every scheduler discipline keeps the degeneracy contract: state
    leaf-for-leaf bitwise equal, and the event log reports the
    resolved default aggregator/attack."""
    task, batch_fn = setup
    fed = _fed(comm=CommConfig(compressor="int8"), sched=sched)
    s0, t0 = _run_sched(task, fed, batch_fn, 3)
    s1, t1 = _run_sched(task, dataclasses.replace(fed, robust=robust),
                        batch_fn, 3)
    _assert_states_equal(s0, s1)
    assert [e.time for e in t0.events] == [e.time for e in t1.events]
    assert all(e.aggregator == "mean" and e.attack == "none"
               and e.byzantine == () and e.dropped == ()
               for e in t1.events)


# ----------------------------------------- kernel-vs-ref conformance
@pytest.mark.parametrize("dtype", ["float32", "bfloat16",
                                   "float8_e4m3fn"])
@pytest.mark.parametrize("trim", [0, 1, 3])
@pytest.mark.parametrize("normalize", [True, False])
def test_robust_agg_kernel_matches_ref_bitwise(dtype, trim, normalize):
    """Pallas kernel == jnp oracle BITWISE, per storage dtype, trim
    count and normalization mode (identical op sequence)."""
    K, R, C = 9, 20, 96
    key = jax.random.PRNGKey(3)
    wires = (10.0 * jax.random.normal(key, (K, R, C))).astype(
        jnp.dtype(dtype))
    weights = jax.random.uniform(jax.random.fold_in(key, 1), (K,),
                                 minval=0.5, maxval=2.0)
    scales = jax.random.uniform(jax.random.fold_in(key, 2), (K,),
                                minval=0.1, maxval=1.0)
    out = robust_agg_flat(wires, weights, scales, trim=trim,
                          normalize=normalize, interpret=True)
    ref = robust_agg_ref(wires, weights, scales, trim=trim,
                         normalize=normalize)
    assert out.dtype == jnp.float32 and ref.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_coordinate_median_is_numpy_median():
    """Maximal trim with uniform weights is the per-coordinate median
    (odd K: exact; the kernel's surviving-mean of one value)."""
    K, R, C = 7, 6, 10
    wires = jax.random.normal(jax.random.PRNGKey(0), (K, R, C))
    ones = jnp.ones((K,), jnp.float32)
    rb = RobustConfig(aggregator="coordinate_median")
    out = ragg.aggregate_stack(rb, wires, ones)
    np.testing.assert_allclose(np.asarray(out),
                               np.median(np.asarray(wires), axis=0),
                               rtol=1e-6, atol=1e-6)


def test_trimmed_mean_bounded_by_survivors():
    """The trimmed mean lies within the per-coordinate min/max of the
    surviving (sorted-interior) values."""
    K, R, C = 10, 5, 8
    trim = 3
    wires = 5.0 * jax.random.normal(jax.random.PRNGKey(1), (K, R, C))
    ones = jnp.ones((K,), jnp.float32)
    out = np.asarray(robust_agg_ref(wires, ones, ones, trim=trim,
                                    normalize=True))
    srt = np.sort(np.asarray(wires), axis=0)[trim:K - trim]
    assert (out >= srt.min(axis=0) - 1e-5).all()
    assert (out <= srt.max(axis=0) + 1e-5).all()


def test_norm_clip_scales_and_resolve():
    """clip_scales: exactly 1.0 inside the ball, clip/||x|| outside;
    resolve degenerates norm_clip only when the clip is off."""
    wires = jnp.stack([jnp.ones((2, 4)), 10.0 * jnp.ones((2, 4))])
    s = np.asarray(ragg.clip_scales(wires, jnp.float32(5.0)))
    nrm1 = float(np.sqrt(8.0)) * 10.0
    assert s[0] == 1.0
    np.testing.assert_allclose(s[1], 5.0 / nrm1, rtol=1e-6)
    assert ragg.resolve(RobustConfig(aggregator="norm_clip",
                                     clip_norm=0.0), 4) == "mean"
    assert ragg.resolve(RobustConfig(aggregator="norm_clip",
                                     clip_norm=1.0), 4) == "norm_clip"
    with pytest.raises(ValueError):
        ragg.resolve(RobustConfig(aggregator="bogus"), 4)


def test_kernel_rejects_full_trim():
    wires = jnp.zeros((4, 2, 2))
    ones = jnp.ones((4,), jnp.float32)
    with pytest.raises(ValueError):
        robust_agg_flat(wires, ones, ones, trim=2, normalize=True,
                        interpret=True)


# --------------------------------------------------- attacks & masks
def test_byzantine_mask_deterministic_and_sized():
    rb = RobustConfig(attack="sign_flip", attack_fraction=0.25, seed=9)
    m1 = ratt.byzantine_mask(rb, 8)
    m2 = ratt.byzantine_mask(rb, 8)
    np.testing.assert_array_equal(m1, m2)
    assert int(m1.sum()) == 2
    m3 = ratt.byzantine_mask(dataclasses.replace(rb, seed=10), 8)
    assert m1.shape == m3.shape
    assert not ratt.byzantine_mask(RobustConfig(), 8).any()
    with pytest.raises(ValueError):
        ratt.byzantine_mask(dataclasses.replace(rb, attack="bogus"), 8)


@pytest.mark.parametrize("attack", ["sign_flip", "scale", "random_wire"])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_attacks_preserve_wire_geometry(attack, dtype):
    """Attack transforms keep the packed stack's shape and dtype, touch
    ONLY the masked rows, and sign_flip is exact negation."""
    rb = RobustConfig(attack=attack, attack_fraction=0.5,
                      attack_scale=3.0)
    wires = jax.random.normal(jax.random.PRNGKey(2), (6, 4, 8)).astype(
        jnp.dtype(dtype))
    mask = jnp.asarray([True, False, True, False, False, True])
    out = ratt.attack_wires(rb, wires, mask, jax.random.PRNGKey(5))
    assert out.shape == wires.shape and out.dtype == wires.dtype
    m = np.asarray(mask)
    np.testing.assert_array_equal(np.asarray(out)[~m],
                                  np.asarray(wires)[~m])
    if attack == "sign_flip":
        np.testing.assert_array_equal(np.asarray(out)[m],
                                      -np.asarray(wires)[m])
    elif attack == "scale":
        np.testing.assert_allclose(
            np.asarray(out)[m].astype(np.float32),
            3.0 * np.asarray(wires)[m].astype(np.float32),
            rtol=1e-2)
    else:
        assert not np.array_equal(np.asarray(out)[m],
                                  np.asarray(wires)[m])


def test_corrupt_labels_only_masked_clients():
    rb = RobustConfig(label_noise_fraction=0.5, label_noise_rate=1.0,
                      seed=3)
    labels = np.zeros((4, 32), np.int64)
    mask = np.array([True, False, True, False])
    out = ratt.corrupt_labels(rb, labels, mask, 10)
    assert out.shape == labels.shape
    np.testing.assert_array_equal(out[~mask], 0)
    # rate 1.0 resamples every masked label uniformly over 10 classes —
    # all-zeros surviving on 64 draws has probability 1e-64
    assert (out[mask] != 0).any()


# ---------------------------------------------- sched event round-trip
def test_sched_event_records_roundtrip_with_robust_fields(setup):
    """to_records/from_records is exact for events carrying the new
    aggregator/attack/byzantine/dropped context."""
    task, batch_fn = setup
    fed = _fed(comm=CommConfig(compressor="int8"),
               sched=SchedConfig(discipline="semisync", buffer_size=4,
                                 latency_profile="lognormal", seed=5),
               robust=RobustConfig(aggregator="trimmed_mean",
                                   trim_fraction=0.3, attack="sign_flip",
                                   attack_fraction=0.5, dropout_prob=0.4,
                                   rejoin_delay_s=3.0))
    _, trace = _run_sched(task, fed, batch_fn, 4)
    assert any(e.byzantine for e in trace.events)
    assert any(e.aggregator != "mean" for e in trace.events)
    back = SchedTrace.from_records(trace.to_records())
    for a, b in zip(trace.events, back.events):
        assert a.aggregator == b.aggregator
        assert a.attack == b.attack
        assert a.byzantine == b.byzantine
        assert a.dropped == b.dropped


# ------------------------------------------------- end-to-end recovery
def test_robust_aggregation_recovers_under_sign_flip(setup):
    """25% sign-flip byzantine clients: plain mean ends with a worse
    training loss than trimmed mean and coordinate median (the CI-sized
    version of the `--only robust` benchmark headline)."""
    task, batch_fn = setup
    base = _fed(lr=0.05)
    atk = dict(attack="sign_flip", attack_fraction=0.25)

    def final_loss(robust):
        fed = dataclasses.replace(base, robust=robust)
        _, m = _run_engine(task, fed, batch_fn, rounds=4)
        return float(m["loss"])

    mean = final_loss(RobustConfig(**atk))
    trimmed = final_loss(RobustConfig(aggregator="trimmed_mean",
                                      trim_fraction=0.3, **atk))
    median = final_loss(RobustConfig(aggregator="coordinate_median",
                                     **atk))
    clean = final_loss(RobustConfig())
    assert trimmed < mean and median < mean
    # robust aggregation lands closer to the clean run than mean does
    assert abs(trimmed - clean) < abs(mean - clean)
    assert abs(median - clean) < abs(mean - clean)


def test_aggregator_registry_is_complete():
    """Every registered aggregator resolves on a non-degenerate config
    (the registry and the dispatch can't drift apart)."""
    cfgs = {
        "mean": RobustConfig(),
        "trimmed_mean": RobustConfig(aggregator="trimmed_mean",
                                     trim_fraction=0.3),
        "coordinate_median": RobustConfig(
            aggregator="coordinate_median"),
        "norm_clip": RobustConfig(aggregator="norm_clip", clip_norm=1.0),
    }
    assert set(cfgs) == set(AGGREGATORS)
    for name, rb in cfgs.items():
        assert ragg.resolve(rb, 8) == name
