"""Client-batched kernel conformance suite.

Pins the tentpole contract of the batched Pallas launches: for every
kernel in the `repro.kernels.KERNELS` registry, the ONE-launch batched
entry point over a packed (C, rows, cols) client stack is **bitwise
equal** to looping the per-client (rows, cols) launch — for fp32,
bf16 and both fp8 resident formats (e4m3/e5m2; the in-VMEM upcast
load path), at ragged sizes where no axis divides the block shape,
under both the committed tuning geometry (blocks=None) and explicit
overrides.

Against the pure-jnp oracles (`repro.kernels.ref`) the pins are
allclose: exact for fp32, one-ulp-class for the narrow formats (the
store rounds once per output, so the band is 2^-mantissa_bits: bf16
2^-8, e4m3 2^-3, e5m2 2^-2).

`stale_accum` is special-cased: its tuned path pins block_k=1 (the
bitwise per-step add order); block_k > 1 folds several wires inside
one kernel invocation, which the backend may contract into FMAs —
allclose, never promised bitwise (see stale_accum_flat's docstring).

The full shape x block sweep is `slow`-marked; the fast tier runs the
ragged base case only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.quantize import (broadcast_roundtrip_batched,
                                    broadcast_roundtrip_flat,
                                    quant_roundtrip_batched,
                                    quant_roundtrip_flat,
                                    sign_roundtrip_batched,
                                    sign_roundtrip_flat,
                                    topk_threshold_batched,
                                    topk_threshold_flat,
                                    uplink_roundtrip_batched,
                                    uplink_roundtrip_flat)
from repro.kernels.sophia_update import (sophia_update_batched,
                                         sophia_update_flat)
from repro.kernels.stale_accum import stale_accum_flat

DTYPES = [jnp.float32, jnp.bfloat16, jnp.float8_e4m3fn,
          jnp.float8_e5m2]
DTYPE_IDS = ["fp32", "bf16", "e4m3", "e5m2"]
#: ragged base case: no axis of (3, 20, 100) divides (2, 8, 96)
N, R, C = 3, 20, 100
RAGGED = (2, 8, 96)
#: None exercises the committed tuning.json lookup at trace time
FAST_BLOCKS = [None, RAGGED]
QMAX = 7
HP = dict(beta1=0.9, beta2=0.95, rho=0.04, eps=1e-12, weight_decay=1e-4)
LR = 3e-3


def _leaves(out):
    return jax.tree.leaves(out)


def _bitwise(a, b):
    for x, y in zip(_leaves(a), _leaves(b)):
        xa, ya = np.asarray(x), np.asarray(y)
        assert xa.dtype == ya.dtype
        np.testing.assert_array_equal(xa, ya)


#: one-ulp-class band per storage format (2^-mantissa_bits): the
#: narrow stores round each output once; fp32 runs the identical fp32
#: ops, but the compiled batched graph may contract mul+add into FMAs
#: where the oracle graph doesn't -> a few fp32 ulps absolute on
#: near-zero residuals
ULP_TOL = {jnp.dtype(jnp.bfloat16): 2 ** -8,
           jnp.dtype(jnp.float8_e4m3fn): 2 ** -3,
           jnp.dtype(jnp.float8_e5m2): 2 ** -2}


def _close_to_ref(out, refd, dtype):
    band = ULP_TOL.get(jnp.dtype(dtype))
    tol = (dict(rtol=band, atol=band) if band
           else dict(rtol=1e-6, atol=1e-6))
    for a, b in zip(_leaves(out), _leaves(refd)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **tol)


def _cases(dtype, n, r, c):
    """kernel name -> (batched fn(blocks), looped fn(), ref fn()); the
    looped twin stacks n per-client 2D launches, the oracle is the
    pure-jnp ref with identical dtype contract."""
    ks = jax.random.split(jax.random.PRNGKey(7), 10)
    f32 = jnp.float32

    def nrm(k, shape, s=1.0, dt=dtype):
        return (s * jax.random.normal(k, shape)).astype(dt)

    x = nrm(ks[0], (n, r, c))
    y = nrm(ks[1], (n, r, c))
    efr = nrm(ks[2], (n, r, c), 0.01)
    g = nrm(ks[3], (n, r, c), 0.5, f32)
    hh = jnp.abs(nrm(ks[4], (n, r, c), 0.02, f32))
    m = nrm(ks[5], (n, r, c), 0.1)
    h = jnp.abs(nrm(ks[6], (n, r, c), 0.01))
    noise = jax.random.uniform(ks[7], (n, r, c), f32)
    theta2 = nrm(ks[8], (r, c))

    xf = x.astype(f32)
    scales = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / QMAX
    d_dn = (theta2.astype(f32) - y.astype(f32)) + efr.astype(f32)
    s_dn = jnp.max(jnp.abs(d_dn), axis=-1, keepdims=True) / QMAX
    d_up = (xf - theta2.astype(f32)) + efr.astype(f32)
    s_up = jnp.max(jnp.abs(d_up), axis=-1, keepdims=True) / QMAX
    cscale = jnp.linspace(0.9, 1.2, n)
    thr = jnp.percentile(jnp.abs(xf).reshape(n, -1), 70.0, axis=1)

    def stackmap(fn):
        def looped():
            outs = [fn(i) for i in range(n)]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        return looped

    return {
        "quant_roundtrip": (
            lambda b: quant_roundtrip_batched(
                x, noise, scales, qmax=QMAX, interpret=True, blocks=b),
            stackmap(lambda i: quant_roundtrip_flat(
                x[i], noise[i], scales[i], qmax=QMAX, interpret=True)),
            lambda: ref.quant_roundtrip_ref(x, noise, scales, qmax=QMAX),
        ),
        # the one server model shared (2D) across the client grid axis
        "broadcast_roundtrip": (
            lambda b: broadcast_roundtrip_batched(
                theta2, y, efr, noise, s_dn, qmax=QMAX, interpret=True,
                blocks=b),
            stackmap(lambda i: broadcast_roundtrip_flat(
                theta2, y[i], efr[i], noise[i], s_dn[i], qmax=QMAX,
                interpret=True)),
            lambda: ref.broadcast_roundtrip_ref(
                theta2[None], y, efr, noise, s_dn, qmax=QMAX),
        ),
        # per-client theta stacks (3D everywhere)
        "broadcast_roundtrip_stacked": (
            lambda b: broadcast_roundtrip_batched(
                x, y, efr, noise, s_dn, qmax=QMAX, interpret=True,
                blocks=b),
            stackmap(lambda i: broadcast_roundtrip_flat(
                x[i], y[i], efr[i], noise[i], s_dn[i], qmax=QMAX,
                interpret=True)),
            lambda: ref.broadcast_roundtrip_ref(
                x, y, efr, noise, s_dn, qmax=QMAX),
        ),
        # shared 2D start: every client trained from the same broadcast
        "uplink_roundtrip": (
            lambda b: uplink_roundtrip_batched(
                x, theta2, efr, noise, s_up, qmax=QMAX, interpret=True,
                blocks=b),
            stackmap(lambda i: uplink_roundtrip_flat(
                x[i], theta2, efr[i], noise[i], s_up[i], qmax=QMAX,
                interpret=True)),
            lambda: ref.uplink_roundtrip_ref(
                x, theta2[None], efr, noise, s_up, qmax=QMAX),
        ),
        "uplink_roundtrip_stacked": (
            lambda b: uplink_roundtrip_batched(
                x, y, efr, noise, s_up, qmax=QMAX, interpret=True,
                blocks=b),
            stackmap(lambda i: uplink_roundtrip_flat(
                x[i], y[i], efr[i], noise[i], s_up[i], qmax=QMAX,
                interpret=True)),
            lambda: ref.uplink_roundtrip_ref(
                x, y, efr, noise, s_up, qmax=QMAX),
        ),
        "sign_roundtrip": (
            lambda b: sign_roundtrip_batched(
                x, cscale, interpret=True, blocks=b),
            stackmap(lambda i: sign_roundtrip_flat(
                x[i], cscale[i], interpret=True)),
            lambda: ref.sign_roundtrip_ref(x, cscale),
        ),
        "topk_threshold": (
            lambda b: topk_threshold_batched(
                x, thr, interpret=True, blocks=b),
            stackmap(lambda i: topk_threshold_flat(
                x[i], thr[i], interpret=True)),
            lambda: ref.topk_threshold_ref(x, thr),
        ),
        "sophia_update": (
            lambda b: sophia_update_batched(
                x, m, h, g, hh, 1.0, LR, interpret=True, blocks=b,
                **HP),
            stackmap(lambda i: sophia_update_flat(
                x[i], m[i], h[i], g[i], hh[i], 1.0, LR, interpret=True,
                **HP)),
            lambda: ref.sophia_update_ref(x, m, h, g, hh, 1.0, lr=LR,
                                          **HP),
        ),
    }


CASE_NAMES = sorted(_cases(jnp.float32, 2, 4, 8))


@pytest.mark.parametrize("dtype", DTYPES, ids=DTYPE_IDS)
@pytest.mark.parametrize("blocks", FAST_BLOCKS, ids=["tuned", "ragged"])
@pytest.mark.parametrize("kernel", CASE_NAMES)
def test_batched_bitwise_equals_looped(kernel, blocks, dtype):
    """ONE batched launch == N per-client launches, bit for bit, for
    both load dtypes, under tuned and ragged-override geometry."""
    batched, looped, _ = _cases(dtype, N, R, C)[kernel]
    _bitwise(batched(blocks), looped())


@pytest.mark.parametrize("dtype", DTYPES, ids=DTYPE_IDS)
@pytest.mark.parametrize("kernel", CASE_NAMES)
def test_batched_matches_ref(kernel, dtype):
    """Batched launch vs the pure-jnp oracle: exact for fp32, one
    bf16 ulp for bf16 resident state."""
    batched, _, oracle = _cases(dtype, N, R, C)[kernel]
    _close_to_ref(batched(None), oracle(), dtype)


@pytest.mark.parametrize("dtype", DTYPES, ids=DTYPE_IDS)
def test_stale_accum_conformance(dtype):
    """Tuned path (block_k pinned 1) is bitwise equal to any explicit
    (1, br, bc) geometry and allclose to the oracle; an indivisible
    block_k falls back to 1 (still bitwise)."""
    K = 6
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    wires = jax.random.normal(ks[0], (K, R, C)).astype(dtype)
    weights = jnp.linspace(0.25, 1.0, K)
    inv = jnp.float32(1.0) / jnp.sum(weights)
    base = stale_accum_flat(wires, weights, inv, interpret=True)
    ragged = stale_accum_flat(wires, weights, inv, interpret=True,
                              blocks=(1, 8, 96))
    _bitwise(base, ragged)
    # K=6 is not divisible by 4 -> block_k falls back to 1
    indiv = stale_accum_flat(wires, weights, inv, interpret=True,
                             blocks=(4, 8, 96))
    _bitwise(base, indiv)
    refd = ref.stale_accum_ref(wires, weights, inv)
    np.testing.assert_allclose(np.asarray(base), np.asarray(refd),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("bk", [2, 3])
def test_stale_accum_blocked_k_is_allclose_not_promised_bitwise(bk):
    """block_k > 1 (explicit opt-in) keeps the add order but allows
    FMA contraction inside the kernel — the contract is allclose."""
    K = 6
    wires = jax.random.normal(jax.random.PRNGKey(13), (K, R, C))
    weights = jnp.linspace(0.25, 1.0, K)
    inv = jnp.float32(1.0) / jnp.sum(weights)
    base = stale_accum_flat(wires, weights, inv, interpret=True)
    blocked = stale_accum_flat(wires, weights, inv, interpret=True,
                               blocks=(bk, 8, 96))
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


def test_tuning_fallback_and_clamp(tmp_path):
    """Missing/malformed tuning tables resolve to the safe defaults;
    resolved blocks never exceed the operand dims."""
    from repro.kernels import tuning
    assert tuning.load_tuning(str(tmp_path / "nope.json")) == {}
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert tuning.load_tuning(str(bad)) == {}
    wrong = tmp_path / "wrong.json"
    wrong.write_text('{"version": 99, "entries": {}}')
    assert tuning.load_tuning(str(wrong)) == {}
    assert tuning.blocks_for("quant_roundtrip", 2, 10, 50,
                             override=(8, 999, 999)) == (2, 10, 50)
    br, bc = tuning.blocks_2d("quant_roundtrip", 10, 50,
                              override=(999, 999))
    assert (br, bc) == (10, 50)


def test_tuning_dtype_chunk_key_precedence(monkeypatch):
    """Suffixed tuning keys resolve most-specific-first —
    ``<kernel>@<dtype>@n<chunk>`` over ``<kernel>@<dtype>`` over the
    bare ``<kernel>`` fallback (which a dtype with no suffixed entry
    also lands on)."""
    from repro.kernels import tuning
    table = {
        "quant_roundtrip": {"block_n": 1, "block_r": 11, "block_c": 13},
        "quant_roundtrip@bfloat16": {"block_n": 2, "block_r": 17,
                                     "block_c": 19},
        "quant_roundtrip@bfloat16@n3": {"block_n": 3, "block_r": 23,
                                        "block_c": 29},
    }
    monkeypatch.setattr(tuning, "load_tuning", lambda path=None: table)
    # chunk-size match wins
    assert tuning.blocks_for("quant_roundtrip", 3, 100, 100,
                             dtype=jnp.bfloat16) == (3, 23, 29)
    # no @n4 entry -> the per-dtype key
    assert tuning.blocks_for("quant_roundtrip", 4, 100, 100,
                             dtype=jnp.bfloat16) == (2, 17, 19)
    # un-suffixed dtype -> bare fallback
    assert tuning.blocks_for("quant_roundtrip", 4, 100, 100,
                             dtype=jnp.float8_e5m2) == (1, 11, 13)
    # no dtype supplied -> bare fallback (the pre-suffix behaviour)
    assert tuning.blocks_for("quant_roundtrip", 4, 100, 100) \
        == (1, 11, 13)
    # the 2D slice resolves per-dtype too
    assert tuning.blocks_2d("quant_roundtrip", 100, 100,
                            dtype=jnp.bfloat16) == (17, 19)


@pytest.mark.slow
@pytest.mark.parametrize("dtype", DTYPES, ids=DTYPE_IDS)
@pytest.mark.parametrize("blocks", [(1, 256, 1024), (2, 64, 256),
                                    (4, 100, 333)])
@pytest.mark.parametrize("shape", [(4, 54, 1024), (5, 257, 1000)])
def test_sweep_batched_equals_looped(shape, blocks, dtype):
    """The full geometry sweep at benchmark-sized stacks: every
    kernel, every block candidate, both dtypes — always bitwise."""
    for kernel, (batched, looped, _) in _cases(dtype, *shape).items():
        _bitwise(batched(blocks), looped())
