"""Federated engine tests: strategy equivalence, aggregation semantics,
optimizer behaviours, hierarchical pod aggregation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core.fed import FedEngine
from repro.data import synthetic as syn
from repro.models.small import MLPTask
from repro.utils.tree import tree_mean_axis0


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    x, y = syn.make_image_data(key, 2048, "mnist", noise=1.0)
    part = syn.dirichlet_partition(jax.random.PRNGKey(1), y, 4, alpha=0.5)
    tr, te = syn.train_test_split(part)
    task = MLPTask(hidden=32)
    return key, x, y, tr, te, task


def _run(task, fed, batches, rounds=3, seed=2):
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(seed))
    for r in range(rounds):
        state, metrics = jax.jit(eng.round)(state, batches,
                                            jax.random.PRNGKey(100 + r))
    return state, metrics


def test_parallel_equals_sequential(setup):
    key, x, y, tr, te, task = setup
    batches = syn.client_batches(key, x, y, tr, 32)
    outs = {}
    for strat in ("parallel", "sequential"):
        fed = FedConfig(num_clients=4, local_iters=3, optimizer="fed_sophia",
                        strategy=strat, lr=0.01, tau=2)
        state, _ = _run(task, fed, batches)
        outs[strat] = state["params"]
    for a, b in zip(jax.tree.leaves(outs["parallel"]),
                    jax.tree.leaves(outs["sequential"])):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_round_counter_and_metrics(setup):
    key, x, y, tr, te, task = setup
    batches = syn.client_batches(key, x, y, tr, 32)
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fed_sophia",
                    lr=0.01)
    state, metrics = _run(task, fed, batches, rounds=5)
    assert int(state["round"]) == 5
    assert jnp.isfinite(metrics["loss"])


def test_fedavg_single_client_single_step_is_sgd(setup):
    """With C=1, J=1, FedAvg round == one SGD step."""
    key, x, y, tr, te, task = setup
    fed = FedConfig(num_clients=1, local_iters=1, optimizer="fedavg", lr=0.05)
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(3))
    batches = syn.client_batches(key, x, y, tr[:1], 32)
    p0 = state["params"]
    state, _ = eng.round(state, batches, jax.random.PRNGKey(0))
    b0 = jax.tree.map(lambda a: a[0], batches)
    g = jax.grad(task.loss)(p0, b0)
    manual = jax.tree.map(lambda t, gg: t - 0.05 * gg, p0, g)
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(manual)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_aggregation_is_client_mean(setup):
    """After one round the server params equal the mean of per-client
    locally-trained params (Eq. 4)."""
    key, x, y, tr, te, task = setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fedavg", lr=0.05)
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(3))
    batches = syn.client_batches(key, x, y, tr, 32)
    p0 = state["params"]
    new, _ = eng.round(state, batches, jax.random.PRNGKey(0))
    locals_ = []
    for i in range(4):
        b = jax.tree.map(lambda a: a[i], batches)
        p, _ = eng._local_sgd(p0, b, None, jnp.asarray(0.05))
        locals_.append(p)
    manual = tree_mean_axis0(jax.tree.map(lambda *xs: jnp.stack(xs), *locals_))
    for a, b in zip(jax.tree.leaves(new["params"]), jax.tree.leaves(manual)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_sophia_trains_better_than_fedavg_rounds(setup):
    """The paper's headline: Fed-Sophia needs fewer rounds than FedAvg."""
    key, x, y, tr, te, task = setup
    teb = syn.client_batches(jax.random.PRNGKey(99), x, y, te, 128)
    accs = {}
    for opt, lr in (("fed_sophia", 0.02), ("fedavg", 0.02)):
        fed = FedConfig(num_clients=4, local_iters=3, optimizer=opt, lr=lr,
                        tau=2)
        eng = FedEngine(task, fed)
        state = eng.init(jax.random.PRNGKey(5))
        rnd = jax.jit(eng.round)
        for r in range(6):
            batches = syn.client_batches(jax.random.fold_in(key, r),
                                         x, y, tr, 32)
            state, _ = rnd(state, batches, jax.random.PRNGKey(200 + r))
        acc = jnp.mean(jax.vmap(
            lambda b: task.accuracy(state["params"], b))(teb))
        accs[opt] = float(acc)
    assert accs["fed_sophia"] >= accs["fedavg"] - 0.02, accs


def test_hessian_refresh_period_round_mode(setup):
    """hessian_every_unit='round' (paper-literal) must also train."""
    key, x, y, tr, te, task = setup
    batches = syn.client_batches(key, x, y, tr, 32)
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fed_sophia",
                    lr=0.01, tau=2, hessian_every_unit="round")
    state, metrics = _run(task, fed, batches, rounds=4)
    assert jnp.isfinite(metrics["loss"])


def test_done_and_fedadam_finite(setup):
    key, x, y, tr, te, task = setup
    batches = syn.client_batches(key, x, y, tr, 32)
    for opt, lr in (("done", 1.0), ("fedadam", 0.02), ("fedyogi", 0.02)):
        fed = FedConfig(num_clients=4, local_iters=2, optimizer=opt, lr=lr)
        state, metrics = _run(task, fed, batches, rounds=3)
        assert jnp.isfinite(metrics["loss"]), opt
        assert all(jnp.all(jnp.isfinite(l))
                   for l in jax.tree.leaves(state["params"])), opt
