"""Observatory-tool tests: tolerant log readers (repro.obs.logio),
the Chrome Trace / Perfetto exporter golden (repro.obs.trace),
obs_report hardening against degenerate logs, obs_diff drift bands,
the dashboard renderer, and the committed bench record files.

The Perfetto golden freezes the exporter's full event layout over a
HAND-BUILT record stream (no jit anywhere, so the fixture is
byte-identical on every platform).  Regenerate after a deliberate
exporter change:

    PYTHONPATH=src python tests/test_obs_tools.py --regen
"""
import copy
import importlib.util
import json
import os
import sys

import pytest

from repro.obs import logio, schema
from repro.obs import trace as obs_trace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(ROOT, "tests", "golden", "perfetto_trace.json")


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(ROOT, "tools", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


obs_report = _load_tool("obs_report")
obs_diff = _load_tool("obs_diff")
obs_dashboard = _load_tool("obs_dashboard")


def _manifest(**meta):
    rec = {"record": "manifest", "schema_version": schema.SCHEMA_VERSION,
           "schema_sha256": schema.fingerprint()}
    if meta:
        rec["meta"] = meta
    return rec


def _traced_records():
    """A hand-built traced semisync log: 3 dispatches over 2 clients,
    2 aggregation events, a host span, the summary.  Every record is
    schema-valid (asserted below) and platform-independent — the
    input both the exporter golden and the diff tests pin."""
    disp = [
        {"record": "sched_dispatch", "trace_id": 1, "client": 0,
         "version": 0, "time_s": 0.0, "arrival_s": 1.25,
         "downlink_s": 0.25, "compute_s": 0.5, "uplink_s": 0.5,
         "downlink_bytes": 1000, "uplink_bytes": 500,
         "hessian_uplink_bytes": 64, "hessian_downlink_bytes": 32},
        {"record": "sched_dispatch", "trace_id": 2, "client": 1,
         "version": 0, "time_s": 0.0, "arrival_s": 2.5,
         "downlink_s": 0.5, "compute_s": 1.0, "uplink_s": 1.0,
         "downlink_bytes": 1000, "uplink_bytes": 500},
        {"record": "sched_dispatch", "trace_id": 3, "client": 0,
         "version": 1, "time_s": 1.25, "arrival_s": 2.75,
         "downlink_s": 0.25, "compute_s": 0.5, "uplink_s": 0.5,
         "downlink_bytes": 1000, "uplink_bytes": 500},
    ]
    events = [
        {"record": "sched_event", "time_s": 1.25, "version": 1,
         "kind": "aggregate", "clients": [0], "staleness": [0],
         "weights": [1.0], "loss": 1.5, "eval_loss": 1.4,
         "clip_fraction": 0.25, "h_staleness": 1.0,
         "cum_uplink_bytes": 500, "cum_downlink_bytes": 1000,
         "cum_hessian_uplink_bytes": 64,
         "cum_hessian_downlink_bytes": 32, "cum_total_bytes": 1596,
         "trace_ids": [1]},
        {"record": "sched_event", "time_s": 2.75, "version": 2,
         "kind": "aggregate", "clients": [1, 0], "staleness": [1, 0],
         "weights": [0.5, 1.0], "loss": 1.2, "eval_loss": 1.1,
         "clip_fraction": 0.5, "h_staleness": 0.0,
         "cum_uplink_bytes": 1500, "cum_downlink_bytes": 3000,
         "cum_hessian_uplink_bytes": 64,
         "cum_hessian_downlink_bytes": 32, "cum_total_bytes": 4596,
         "trace_ids": [2, 3]},
    ]
    span = {"record": "span", "name": "dispatch", "t_wall_s": 0.001,
            "wall_s": 0.002, "virtual_s": 1.25, "trace_id": 3}
    summary = {"record": "sched_summary", "discipline": "semisync",
               "events": 2, "final_time_s": 2.75,
               "cum_total_bytes": 4596,
               "staleness_hist": [[0, 2], [1, 1]]}
    return disp + events + [span, summary]


def test_traced_fixture_records_are_schema_valid():
    for r in [_manifest()] + _traced_records():
        schema.validate_record(r)


# --------------------------------------------------- logio robustness
def test_read_records_missing_and_empty(tmp_path):
    with pytest.raises(logio.ObsLogError, match="no such file"):
        logio.read_records(str(tmp_path / "gone.jsonl"))
    p = tmp_path / "empty.jsonl"
    p.write_text("  \n")
    with pytest.raises(logio.ObsLogError, match="empty log"):
        logio.read_records(str(p))


def test_read_records_drops_truncated_final_line(tmp_path, capsys):
    """The tail of a live or killed run is not corruption: the final
    partial line is dropped with a warning, the rest loads."""
    p = tmp_path / "live.jsonl"
    good = [_manifest(), _traced_records()[0]]
    lines = [json.dumps(r, sort_keys=True) for r in good]
    p.write_text("\n".join(lines) + '\n{"record": "sched_ev')
    recs = logio.read_records(str(p))
    assert recs == good
    assert "truncated final line" in capsys.readouterr().err


def test_read_records_rejects_mid_log_corruption(tmp_path):
    p = tmp_path / "corrupt.jsonl"
    m = json.dumps(_manifest())
    p.write_text(f"{m}\nNOT JSON\n{m}\n")
    with pytest.raises(logio.ObsLogError, match="line 2"):
        logio.read_records(str(p))


def test_read_records_json_array_and_single_record(tmp_path):
    recs = [_manifest(), _traced_records()[0]]
    p = tmp_path / "arr.json"
    p.write_text(json.dumps(recs, indent=1))
    assert logio.read_records(str(p)) == recs
    p2 = tmp_path / "one.json"
    p2.write_text(json.dumps(_manifest()))
    assert logio.read_records(str(p2)) == [_manifest()]


def test_read_records_legacy_bench_dicts(tmp_path):
    """Pre-v2 bench files still load: {name: row} and the two-level
    {"baseline": {name: row}} shape become bench-shaped records."""
    one = tmp_path / "one_level.json"
    one.write_text(json.dumps({"regime-a": {"layout_ops": 3}},
                              indent=1))
    recs = logio.read_records(str(one))
    assert recs == [{"record": "bench", "name": "regime-a",
                     "layout_ops": 3}]
    two = tmp_path / "two_level.json"
    two.write_text(json.dumps(
        {"baseline": {"regime-a": {"layout_ops": 3}},
         "current": {"regime-a": {"layout_ops": 2}}}, indent=1))
    names = {r["name"] for r in logio.read_records(str(two))}
    assert names == {"baseline/regime-a", "current/regime-a"}


def test_manifest_of():
    recs = _traced_records()
    assert logio.manifest_of(recs) == {}
    assert logio.manifest_of([_manifest()] + recs) == _manifest()


# ----------------------------------------------- Perfetto export golden
def test_chrome_trace_matches_golden():
    doc = obs_trace.chrome_trace([_manifest()] + _traced_records())
    with open(GOLDEN) as f:
        golden = json.load(f)
    assert doc == golden, (
        "Perfetto export diverged from the committed golden — if the "
        "exporter change is deliberate, regenerate with "
        "`PYTHONPATH=src python tests/test_obs_tools.py --regen`")


def test_chrome_trace_is_structurally_valid_and_deterministic():
    recs = _traced_records()
    doc = obs_trace.chrome_trace(recs)
    assert obs_trace.validate_chrome_trace(doc) == []
    assert json.dumps(doc, sort_keys=True) == json.dumps(
        obs_trace.chrome_trace(list(recs)), sort_keys=True)
    # 3 slices per dispatch + 1 apply per event + the host span
    slices = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(slices) == 3 * 3 + 2 + 1
    # uplink slices end exactly at the authoritative arrival_s
    ups = [e for e in slices if e["name"] == "uplink"]
    assert {round(e["ts"] + e["dur"], 3) for e in ups} == {
        1.25e6, 2.5e6, 2.75e6}
    # counter tracks: loss + both probes per event
    assert sum(1 for e in doc["traceEvents"] if e["ph"] == "C") == 6


def test_chrome_trace_without_contexts_degrades_to_instants():
    """A tracing-off log (events without trace_ids) still exports: the
    apply slices degrade to instant markers."""
    evs = [dict(e) for e in _traced_records()
           if e["record"] == "sched_event"]
    for e in evs:
        del e["trace_ids"]
    doc = obs_trace.chrome_trace(evs)
    assert obs_trace.validate_chrome_trace(doc) == []
    applies = [e for e in doc["traceEvents"] if e["name"] == "apply"]
    assert applies and all(e["ph"] == "i" for e in applies)


def test_validate_chrome_trace_catches_breakage():
    assert obs_trace.validate_chrome_trace({}) == [
        "not a Chrome trace: missing top-level 'traceEvents'"]
    assert obs_trace.validate_chrome_trace({"traceEvents": []})
    bad = obs_trace.chrome_trace(_traced_records())
    bad["traceEvents"][-1] = {k: v
                              for k, v in bad["traceEvents"][-1].items()
                              if k != "ts"}
    assert any("missing keys" in e
               for e in obs_trace.validate_chrome_trace(bad))
    neg = {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 0,
                            "ts": 5.0, "dur": -1.0}]}
    assert any("negative dur" in e
               for e in obs_trace.validate_chrome_trace(neg))
    back = {"traceEvents": [
        {"name": "a", "ph": "i", "pid": 1, "tid": 0, "ts": 5.0},
        {"name": "b", "ph": "i", "pid": 1, "tid": 0, "ts": 4.0}]}
    assert any("goes backwards" in e
               for e in obs_trace.validate_chrome_trace(back))


# ------------------------------------------------ obs_report hardening
def test_obs_report_validate_accepts_current_log(capsys):
    rc = obs_report.validate("log", [_manifest()] + _traced_records())
    assert rc == 0
    assert "valid" in capsys.readouterr().out


def test_obs_report_validate_missing_manifest(capsys):
    rc = obs_report.validate("log", _traced_records())
    assert rc == 1
    assert "first record must be the run manifest" \
        in capsys.readouterr().out


def test_obs_report_validate_no_content_records(capsys):
    """A log with zero sched_event/round records is a setup-only run —
    validation names the problem instead of crashing on it."""
    rc = obs_report.validate("log", [_manifest()])
    assert rc == 1
    assert "no content records" in capsys.readouterr().out


def test_obs_report_validate_versions(capsys):
    bench = {"record": "bench", "name": "x", "layout_ops": 1}
    old = {"record": "manifest", "schema_version": 1,
           "schema_sha256": "0" * 64}
    # supported old version: fingerprint mismatch tolerated
    assert obs_report.validate("log", [old, bench]) == 0
    unsupported = dict(old, schema_version=99)
    assert obs_report.validate("log", [unsupported, bench]) == 1
    drifted = dict(old, schema_version=schema.SCHEMA_VERSION)
    assert obs_report.validate("log", [drifted, bench]) == 1
    out = capsys.readouterr().out
    assert "not supported" in out and "schema_sha256" in out


def test_obs_report_summarize_degenerate_logs(capsys):
    """Summary mode renders best-effort on manifest-less and
    trajectory-less logs — satellite: no tracebacks on degenerate
    input."""
    assert obs_report.summarize("log", [{"record": "bench",
                                         "name": "x"}]) == 0
    assert "no manifest record" in capsys.readouterr().out
    assert obs_report.summarize("log", [_manifest()]) == 0
    assert "no trajectory records" in capsys.readouterr().out
    assert obs_report.summarize(
        "log", [_manifest()] + _traced_records()) == 0
    out = capsys.readouterr().out
    assert "trace contexts: 3 dispatches" in out
    assert "staleness histogram" in out


def test_obs_report_load_exits_cleanly_on_missing_file(tmp_path):
    with pytest.raises(SystemExit, match="no such file"):
        obs_report.load(str(tmp_path / "gone.jsonl"))


# ------------------------------------------------------- obs_diff bands
def test_obs_diff_self_compare_is_zero_drift():
    recs = [_manifest()] + _traced_records()
    rows, failures = obs_diff.diff(recs, recs, {}, 0.0)
    assert failures == []
    assert rows and all(worst == 0.0 for _, _, _, worst, _ in rows)


def test_obs_diff_int_counters_are_exact_despite_bands():
    a = [_manifest(), {"record": "bench", "name": "x",
                       "total_bytes": 100}]
    b = copy.deepcopy(a)
    b[1]["total_bytes"] = 101
    _, failures = obs_diff.diff(a, b, {"total_bytes": 1.0}, 1.0)
    assert any("total_bytes" in f for f in failures)


def test_obs_diff_float_metrics_respect_bands():
    a = [_manifest(), {"record": "bench", "name": "x",
                       "us_per_round": 100.0}]
    b = copy.deepcopy(a)
    b[1]["us_per_round"] = 100.1
    _, strict = obs_diff.diff(a, b, {}, 0.0)
    assert any("us_per_round" in f for f in strict)
    _, banded = obs_diff.diff(a, b, {"us_per_round": 0.01}, 0.0)
    assert banded == []


def test_obs_diff_reports_unmatched_and_schema_drift():
    recs = _traced_records()
    a = [_manifest()] + recs
    b = [dict(_manifest(), schema_sha256="f" * 64)] + recs[:-2]
    _, failures = obs_diff.diff(a, b, {}, 0.0)
    assert any("fingerprints differ" in f for f in failures)
    assert any("only in run A" in f for f in failures)


def test_obs_diff_aligns_bench_rows_by_name_not_position():
    row = {"record": "bench", "name": "x", "layout_ops": 5}
    other = {"record": "bench", "name": "y", "layout_ops": 9}
    a = [_manifest(), row, other]
    b = [_manifest(), other, row]          # same rows, reordered
    rows, failures = obs_diff.diff(a, b, {}, 0.0)
    assert failures == []
    assert all(worst == 0.0 for _, _, _, worst, _ in rows)


# -------------------------------------------------- dashboard renderer
def test_dashboard_sparkline():
    assert obs_dashboard.sparkline([]) == "(no data)"
    line = obs_dashboard.sparkline([0, 1, 2, 3])
    assert line[0] == obs_dashboard.SPARK[0]
    assert line[-1] == obs_dashboard.SPARK[-1]
    assert len(obs_dashboard.sparkline(list(range(500)), width=48)) == 48


def test_dashboard_render_sections():
    txt = obs_dashboard.render(
        [_manifest(arch="mlp")] + _traced_records(), "run.jsonl")
    assert "loss" in txt and "streams:" in txt
    assert "staleness histogram" in txt
    assert "3 dispatch contexts" in txt
    serve = {"record": "serve", "tokens_per_s": 12.5, "prefill_s": 0.5,
             "decode_steps": 8, "batch": 2, "decode_p50_ms": 1.0,
             "decode_p95_ms": 2.0, "decode_p99_ms": 3.0}
    txt = obs_dashboard.render([_manifest(), serve], "serve.jsonl")
    assert "tok/s" in txt and "p50/p95/p99" in txt


# -------------------------------------- committed bench record files
BENCH_FILES = ("experiments/bench_comm.json",
               "experiments/bench_sched.json",
               "experiments/bench_robust.json",
               "BENCH_engine.json")


@pytest.mark.parametrize("rel", BENCH_FILES)
def test_committed_bench_files_are_validated_record_logs(rel):
    """The committed benchmark trajectories are obs record logs:
    manifest first (current fingerprint — they are regenerated through
    the recorder), every row a schema-valid `bench` record with a
    unique name (what obs_diff aligns on)."""
    recs = logio.read_records(os.path.join(ROOT, rel))
    assert recs[0]["record"] == "manifest"
    assert recs[0]["schema_sha256"] == schema.fingerprint()
    names = set()
    for r in recs[1:]:
        schema.validate_record(r)
        assert r["record"] == "bench"
        names.add(r["name"])
    assert len(names) == len(recs) - 1


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="rewrite the committed Perfetto export golden")
    if ap.parse_args().regen:
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        doc = obs_trace.chrome_trace([_manifest()] + _traced_records())
        errors = obs_trace.validate_chrome_trace(doc)
        if errors:
            sys.exit("refusing to freeze an invalid trace:\n  "
                     + "\n  ".join(errors))
        with open(GOLDEN, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN}")
