"""Exactness/equivalence guarantees of the §Perf knobs (EXPERIMENTS.md):

  * pad_attn_heads — zero-padded q-heads are a mathematical no-op on the
    forward AND stay zero through Sophia training (zero grad, decay, clip);
  * grad_microbatches — micro-accumulated grads equal full-batch grads;
  * slstm_unroll — scan unrolling does not change sLSTM outputs;
  * scan_compute_dtype / attn_chunk_threshold — variants stay close to the
    fp32 / chunked baselines;
  * hessian_every_unit=round — the hoisted GNB path matches step mode when
    tau_step = J (same refresh cadence).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, ModelConfig
from repro.core.fed import FedEngine
from repro.models import layers as L
from repro.models import recurrent as R
from repro.models import transformer as T

BASE = dict(num_layers=2, d_model=64, num_heads=3, num_kv_heads=3,
            d_ff=128, vocab_size=96)


def _cfg(**kw):
    d = {**BASE, **kw}
    fam = d.pop("family", "dense")
    return ModelConfig(name=d.pop("name", "t"), family=fam, **d)


def _batch(key, cfg, B=4, S=16):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    lab = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                             cfg.vocab_size)
    return {"tokens": tok, "labels": lab}


# ------------------------------------------------------------ head padding
def test_pad_attn_heads_forward_exact():
    """Padded-head model == unpadded model on the same weights."""
    key = jax.random.PRNGKey(0)
    cfg = _cfg(qk_norm=True, num_heads=4, num_kv_heads=2)
    cfgp = dataclasses.replace(cfg, pad_attn_heads=6)     # 4 -> 6, kv=2
    params = T.init_lm(key, cfg)
    paramsp = T.init_lm(key, cfgp)
    mask = np.asarray(L.pad_head_mask(cfgp))              # (Hp*hd,)
    real_idx = np.nonzero(mask)[0]

    # graft real weights into the group-interleaved padded slots
    def graft(pp, p, name):
        pp = jnp.zeros_like(pp)
        if name == "wq":
            return pp.at[..., :, real_idx].set(p)
        return pp.at[..., real_idx, :].set(p)

    for b in paramsp:
        if not b.startswith(("blocks", "rem")):
            continue
        mix_p = params[b]["mixer"]
        mix_pp = paramsp[b]["mixer"]
        mix_pp["wq"] = graft(mix_pp["wq"], mix_p["wq"], "wq")
        mix_pp["wo"] = graft(mix_pp["wo"], mix_p["wo"], "wo")
        for k in ("wk", "wv", "q_norm", "k_norm"):
            if k in mix_p:
                mix_pp[k] = mix_p[k]
        for k in paramsp[b]:
            if k != "mixer":
                paramsp[b][k] = params[b][k]
    for k in ("embed", "final_norm", "lm_head"):
        if k in params:
            paramsp[k] = params[k]

    batch = _batch(jax.random.fold_in(key, 7), cfg)
    lo, _, _ = T.forward(params, cfg, batch)
    lp, _, _ = T.forward(paramsp, cfgp, batch)
    np.testing.assert_allclose(np.asarray(lo), np.asarray(lp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_pad_attn_heads_zeros_stay_zero_under_training():
    """One federated Sophia round leaves the padded wq/wo regions at 0."""
    key = jax.random.PRNGKey(1)
    cfgp = _cfg(pad_attn_heads=6, num_heads=4, num_kv_heads=2)
    task = T.LMTask(cfgp)
    fed = FedConfig(num_clients=2, local_iters=3, optimizer="fed_sophia",
                    tau=2, lr=1e-2, weight_decay=1e-2)
    eng = FedEngine(task, fed)
    state = eng.init(key)
    C = fed.num_clients
    batch = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (C,) + x.shape),
        _batch(jax.random.fold_in(key, 3), cfgp))
    state, _ = jax.jit(eng.round)(state, batch, jax.random.fold_in(key, 9))
    pad = ~np.asarray(L.pad_head_mask(cfgp))     # padded-slot mask
    for b, bp in state["params"].items():
        if not b.startswith(("blocks", "rem")):
            continue
        wq, wo = np.asarray(bp["mixer"]["wq"]), np.asarray(bp["mixer"]["wo"])
        assert np.all(wq[..., :, pad] == 0.0), f"{b}: padded wq drifted"
        assert np.all(wo[..., pad, :] == 0.0), f"{b}: padded wo drifted"
        assert np.any(wq[..., :, ~pad] != 0.0)   # real region did train


# --------------------------------------------------------- grad microbatch
def test_grad_microbatches_exact():
    key = jax.random.PRNGKey(2)
    cfg = _cfg()
    task = T.LMTask(cfg)
    params = task.init(key)
    batch = _batch(jax.random.fold_in(key, 1), cfg, B=8)

    full = FedEngine(task, FedConfig(num_clients=1, grad_microbatches=1))
    micro = FedEngine(task, FedConfig(num_clients=1, grad_microbatches=4))
    l1, g1 = full._value_and_grad(task.loss, params, batch, None)
    l2, g2 = micro._value_and_grad(task.loss, params, batch, None)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)


# ------------------------------------------------------------ sLSTM unroll
def test_slstm_unroll_equivalent():
    key = jax.random.PRNGKey(3)
    cfg = _cfg(family="ssm", num_heads=2, num_kv_heads=2,
               block_pattern=("s",), slstm_proj_factor=2.0)
    p = R.init_slstm(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    pos = jnp.arange(32)[None].repeat(2, 0)
    out1, _ = R.slstm_apply(p, cfg, x, pos)
    cfg16 = dataclasses.replace(cfg, slstm_unroll=16)
    out2, _ = R.slstm_apply(p, cfg16, x, pos)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------- mLSTM scan dtype / attn dense
def test_mlstm_bf16_scan_close_to_fp32():
    key = jax.random.PRNGKey(4)
    cfg = _cfg(family="ssm", num_heads=2, num_kv_heads=2,
               block_pattern=("m",))
    p = R.init_mlstm(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 256, cfg.d_model))
    pos = jnp.arange(256)[None].repeat(2, 0)
    ref, _ = R.mlstm_apply(p, cfg, x, pos)
    cfgb = dataclasses.replace(cfg, scan_compute_dtype="bfloat16")
    opt, _ = R.mlstm_apply(p, cfgb, x, pos)
    # bf16 operands, fp32 accumulation: ~1e-2 relative
    err = np.max(np.abs(np.asarray(ref) - np.asarray(opt))) / (
        np.max(np.abs(np.asarray(ref))) + 1e-9)
    assert err < 5e-2, err


def test_attn_threshold_dense_matches_chunked_forward():
    key = jax.random.PRNGKey(5)
    cfg = _cfg()                                   # threshold 2048
    cfg_dense = dataclasses.replace(cfg, attn_chunk_threshold=10**9)
    cfg_chunk = dataclasses.replace(cfg, attn_chunk_threshold=0,
                                    attn_kv_chunk=16)
    params = T.init_lm(key, cfg)
    batch = _batch(jax.random.fold_in(key, 1), cfg, B=2, S=64)
    ld, _, _ = T.forward(params, cfg_dense, batch)
    lc, _, _ = T.forward(params, cfg_chunk, batch)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lc),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------- GNB round-mode hoist
@pytest.mark.slow
def test_hessian_round_mode_matches_step_mode():
    """tau_round=1 with J local iters == tau_step=J (same refresh cadence,
    same estimate params: the round-start theta), up to the estimator's
    RNG stream. Use tau such that refresh fires at j==0 only."""
    key = jax.random.PRNGKey(6)
    cfg = _cfg()
    task = T.LMTask(cfg)
    J = 3
    com = dict(num_clients=2, local_iters=J, optimizer="fed_sophia",
               lr=1e-2, tau_rng_invariant=None)
    com.pop("tau_rng_invariant")
    step = FedEngine(task, FedConfig(tau=J, hessian_every_unit="step", **com))
    rnd = FedEngine(task, FedConfig(tau=1, hessian_every_unit="round", **com))
    state_s = step.init(key)
    state_r = rnd.init(key)
    C = 2
    batch = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (C,) + x.shape),
        _batch(jax.random.fold_in(key, 2), cfg))
    rng = jax.random.fold_in(key, 3)
    state_s, ms = jax.jit(step.round)(state_s, batch, rng)
    state_r, mr = jax.jit(rnd.round)(state_r, batch, rng)
    # identical update schedule; only the GNB label-sampling fold differs.
    # loss trajectories must match exactly at j=0 (pre-update loss):
    np.testing.assert_allclose(float(ms["loss"]), float(mr["loss"]),
                               rtol=5e-3)
    # and the aggregated params agree to GNB-sampling noise
    for a, b in zip(jax.tree.leaves(state_s["params"]),
                    jax.tree.leaves(state_r["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0.1, atol=5e-3)
