"""repro.obs tests: frozen schema golden, record validation, exact
int64 byte counters, Sophia health probes (value correctness, bitwise
probes-on/off state equality, layout-op neutrality), the packed device
metrics buffer, sinks/manifest, the Eq. 13-14 energy wiring over exact
wire bytes, and SchedTrace <-> JSONL round-trip determinism.

The schema golden freezes the FULL canonical registry dump (metric
names, dtypes, units, record field sets) against
``tests/golden/obs_schema.json`` — any schema edit is a deliberate,
reviewed event.  Regenerate:

    PYTHONPATH=src python tests/test_obs.py --regen
"""
import dataclasses
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs.base import FedConfig, ObsConfig, SchedConfig
from repro.core.fed import FedEngine
from repro.data import synthetic as syn
from repro.metrics import energy
from repro.models.small import MLPTask
from repro.obs import schema as obs_schema
from repro.sched import SchedTrace, VirtualScheduler

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "obs_schema.json")


# ------------------------------------------------------- schema golden
def test_schema_matches_golden():
    with open(GOLDEN) as f:
        golden = json.load(f)
    assert obs.describe() == golden, (
        "obs schema diverged from the committed golden — if the change "
        "is deliberate, regenerate with "
        "`python tests/test_obs.py --regen` (and bump SCHEMA_VERSION "
        "on any removal/retype)")


def test_fingerprint_is_stable_and_canonical():
    assert obs.fingerprint() == obs.fingerprint()
    # canonical dump is valid JSON of describe()
    assert json.loads(obs_schema.canonical_json()) == obs.describe()


def test_every_record_field_is_a_registered_metric():
    for name, rt in obs_schema.RECORDS.items():
        for f in rt.required + rt.optional:
            assert f in obs_schema.METRICS, (name, f)


# --------------------------------------------------- record validation
def _round_rec(**over):
    rec = {"record": "round", "round": 0, "loss": 1.5, "lr": 0.01,
           "participants": 4, "uplink_bytes": 100, "downlink_bytes": 100,
           "hessian_uplink_bytes": 0, "hessian_downlink_bytes": 0,
           "total_bytes": 200, "cum_total_bytes": 200,
           "energy_J": 0.1, "carbon_kg": 1e-8}
    rec.update(over)
    return rec


def test_validate_accepts_valid_round():
    assert obs.validate_record(_round_rec()) == _round_rec()


def test_validate_rejects_unknown_type_missing_and_extra_fields():
    with pytest.raises(obs.ObsSchemaError, match="unknown record type"):
        obs.validate_record({"record": "bogus"})
    with pytest.raises(obs.ObsSchemaError, match="missing required"):
        rec = _round_rec()
        del rec["total_bytes"]
        obs.validate_record(rec)
    with pytest.raises(obs.ObsSchemaError, match="not in the schema"):
        obs.validate_record(_round_rec(surprise=1))


def test_byte_counters_reject_floats_and_bools():
    """The whole point of the schema: byte counts never pass through
    floats (satellite: the float32 in-jit mirrors lose exactness above
    2^24)."""
    with pytest.raises(obs.ObsSchemaError, match="exact int64"):
        obs.validate_record(_round_rec(uplink_bytes=100.0))
    with pytest.raises(obs.ObsSchemaError, match="exact int64"):
        obs.validate_record(_round_rec(participants=True))
    with pytest.raises(obs.ObsSchemaError, match="int64 range"):
        obs.validate_record(_round_rec(total_bytes=2 ** 63))


def test_int64_exactness_beyond_float32_and_float64():
    """2^53+1 is not representable in float64 (nor 2^24+1 in float32);
    the schema carries it exactly through a JSON round-trip."""
    big = 2 ** 53 + 1
    assert float(big) != big                  # would be lost as a float
    rec = _round_rec(total_bytes=big, cum_total_bytes=big)
    back = json.loads(json.dumps(obs.validate_record(rec)))
    assert back["total_bytes"] == big


# -------------------------------------------------------- energy model
def test_channel_rate_hand_computed():
    """Default ChannelModel: R = B log2(1 + P/(d B N0)) with B=1MHz,
    P=0.1W, d=1e12 -> SNR=1 -> R = 2 Mb/s exactly (Eq. 13)."""
    chan = energy.ChannelModel()
    assert chan.rate() == pytest.approx(2e6, rel=1e-12)


def test_tx_energy_joules_hand_computed():
    """Eq. 14 over exact bytes: 250 kB = 2 Mb at 2 Mb/s = 1 s at
    0.1 W = 0.1 J."""
    chan = energy.ChannelModel()
    assert energy.tx_energy_joules(250_000, chan) == pytest.approx(0.1)
    # consistency with the per-round raw-fp32 helper: n params = 4n bytes
    n = 12_345
    assert energy.tx_energy_joules(4 * n, chan) == pytest.approx(
        chan.tx_energy_per_round(n))
    assert energy.tx_energy_joules(0) == 0.0


# ------------------------------------------------------- Sophia probes
@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    x, y = syn.make_image_data(key, 512, "mnist", noise=1.0)
    part = syn.dirichlet_partition(jax.random.PRNGKey(1), y, 4, alpha=0.5)
    tr, _ = syn.train_test_split(part)
    task = MLPTask(hidden=16)

    def batch_fn(v):
        return syn.client_batches(jax.random.fold_in(key, 100 + v),
                                  x, y, tr, 32)

    return task, batch_fn


def _fed(**kw):
    base = dict(num_clients=4, local_iters=2, optimizer="fed_sophia",
                lr=0.01, tau=2)
    base.update(kw)
    return FedConfig(**base)


RUN_RNG = jax.random.PRNGKey(7)


def _run_rounds(task, batch_fn, fed, rounds=3):
    eng = FedEngine(task, fed)
    state = eng.pack_state(eng.init(jax.random.PRNGKey(2)))
    rf = eng.round_fn(donate=False)
    metrics = None
    for r in range(rounds):
        state, metrics = rf(state, batch_fn(r),
                            jax.random.fold_in(RUN_RNG, r))
    return state, metrics


def test_probes_on_state_bitwise_equals_probes_off(setup):
    """The acceptance bar: enabling probes changes ONLY the metrics
    dict — every state leaf is bitwise identical."""
    task, batch_fn = setup
    s_off, m_off = _run_rounds(task, batch_fn, _fed())
    s_on, m_on = _run_rounds(task, batch_fn,
                             _fed(obs=ObsConfig(probes=True)))
    l_off, l_on = jax.tree.leaves(s_off), jax.tree.leaves(s_on)
    assert len(l_off) == len(l_on)
    for a, b in zip(l_off, l_on):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m_off["loss"]) == float(m_on["loss"])
    for k in obs.PROBE_METRICS:
        assert k in m_on and k not in m_off


def test_probe_values(setup):
    task, batch_fn = setup
    fed = _fed(obs=ObsConfig(probes=True))
    _, m = _run_rounds(task, batch_fn, fed, rounds=3)
    clip = float(m["clip_fraction"])
    assert 0.0 <= clip <= 1.0
    assert float(m["m_norm"]) > 0 and float(m["h_norm"]) > 0
    # hessian_every_unit="step" (default), J=2, tau=2: after round
    # r=2 the last local step index is (r+1)*J-1 = 5 ->
    # staleness 5 % 2 = 1, refreshes 5 // 2 + 1 = 3
    assert float(m["h_staleness"]) == 1.0
    assert float(m["gnb_refreshes"]) == 3.0


def test_probe_staleness_round_unit(setup):
    task, batch_fn = setup
    fed = _fed(obs=ObsConfig(probes=True), hessian_every_unit="round",
               tau=3)
    _, m = _run_rounds(task, batch_fn, fed, rounds=4)
    # round unit: last refresh opportunity index is r=3 -> 3 % 3 = 0,
    # 3 // 3 + 1 = 2
    assert float(m["h_staleness"]) == 0.0
    assert float(m["gnb_refreshes"]) == 2.0


def test_sophia_health_hand_built():
    """Value correctness on a hand-built optimizer state: h=1
    everywhere, m ramp -> clip fraction is the exact count of
    |m| >= rho coordinates."""
    from repro.core.sophia import SophiaState
    from repro.obs.probes import sophia_health
    C, R, Ccols = 2, 2, 4
    total = R * Ccols
    m = jnp.stack([jnp.full((R, Ccols), 0.5),
                   jnp.zeros((R, Ccols))])          # half the coords clip
    h = jnp.ones((C, R, Ccols))
    fed = _fed(rho=0.04)
    out = sophia_health(SophiaState(m=m, h=h), 0, fed, total)
    assert float(out["clip_fraction"]) == pytest.approx(0.5)
    # RMS over clients: sqrt(sum(m^2)/C), sqrt(sum(h^2)/C)
    assert float(out["m_norm"]) == pytest.approx(
        math.sqrt(0.25 * total / C))
    assert float(out["h_norm"]) == pytest.approx(
        math.sqrt(C * total / C))


def test_probes_require_stateful_sophia(setup):
    task, _ = setup
    with pytest.raises(ValueError, match="probes"):
        FedEngine(task, _fed(optimizer="fedavg",
                             obs=ObsConfig(probes=True)))


def test_probes_add_no_layout_ops(setup):
    """Probe math is elementwise/reduction only — the layout-op gate
    (benchmarks/run.py LAYOUT_PRIMS) must see the identical count."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    try:
        from benchmarks.run import _count_layout_ops
    finally:
        sys.path.pop(0)
    task, batch_fn = setup
    counts = {}
    for name, fed in (("off", _fed()),
                      ("on", _fed(obs=ObsConfig(probes=True)))):
        eng = FedEngine(task, fed)
        state = eng.pack_state(eng.init(jax.random.PRNGKey(2)))
        jaxpr = jax.make_jaxpr(eng.round)(state, batch_fn(0), RUN_RNG)
        counts[name] = _count_layout_ops(jaxpr.jaxpr)
    assert counts["on"] == counts["off"]


# ------------------------------------------------------- device buffer
def test_metrics_accumulator_batches_rows():
    acc = obs.MetricsAccumulator(4)
    for i in range(3):
        acc.add({"a": jnp.asarray(float(i)), "b": jnp.asarray(10.0 + i)})
    assert len(acc) == 3
    rows = acc.flush()
    assert rows == [{"a": float(i), "b": 10.0 + i} for i in range(3)]
    assert len(acc) == 0                      # reset after flush
    acc.add({"a": jnp.asarray(5.0), "b": jnp.asarray(6.0)})
    assert acc.flush() == [{"a": 5.0, "b": 6.0}]


def test_metrics_accumulator_guards():
    acc = obs.MetricsAccumulator(1)
    acc.add({"a": jnp.asarray(1.0)})
    with pytest.raises(ValueError, match="full"):
        acc.add({"a": jnp.asarray(2.0)})
    acc.flush()
    with pytest.raises(ValueError, match="names"):
        acc.add({"z": jnp.asarray(1.0)})


# ---------------------------------------------------- sinks / recorder
def test_run_recorder_jsonl_and_manifest(tmp_path):
    path = str(tmp_path / "run.jsonl")
    rec = obs.RunRecorder(path, meta={"arch": "mlp"})
    rec.emit(_round_rec())
    rec.emit(_round_rec(round=1, cum_total_bytes=400))
    rec.close()
    lines = [json.loads(l) for l in open(path)]
    assert lines[0]["record"] == "manifest"
    assert lines[0]["schema_sha256"] == obs.fingerprint()
    assert lines[0]["meta"] == {"arch": "mlp"}
    assert [l["record"] for l in lines[1:]] == ["round", "round"]
    man = json.load(open(rec.manifest_path))
    assert man["records"] == {"manifest": 1, "round": 2}
    assert man["schema_version"] == obs.SCHEMA_VERSION
    # the ring mirrors the stream for in-process consumers
    assert [r["record"] for r in rec.ring.records()][-1] == "round"


def test_run_recorder_validates_on_emit(tmp_path):
    rec = obs.RunRecorder(str(tmp_path / "run.jsonl"))
    with pytest.raises(obs.ObsSchemaError):
        rec.emit({"record": "round"})


# --------------------------------------------- sched trace round-trip
def _run_sched(task, batch_fn, fed, events, seed=2):
    eng = FedEngine(task, fed)
    sched = VirtualScheduler(eng, batch_fn)
    state = eng.init(jax.random.PRNGKey(seed))
    return sched.run(state, events, RUN_RNG)


@pytest.mark.parametrize("disc", ["semisync", "async"])
def test_sched_trace_jsonl_roundtrip_deterministic(setup, disc):
    """Two identical scheduler runs serialize to byte-identical JSONL;
    from_records(to_records(t)) re-serializes exactly."""
    task, batch_fn = setup
    fed = _fed(obs=ObsConfig(probes=True),
               sched=SchedConfig(discipline=disc))
    chan = energy.ChannelModel()

    def lines(trace):
        return [json.dumps(r, sort_keys=True)
                for r in trace.to_records(channel=chan)]

    _, t1 = _run_sched(task, batch_fn, fed, 3)
    _, t2 = _run_sched(task, batch_fn, fed, 3)
    assert lines(t1) == lines(t2)
    for rec in t1.to_records(channel=chan):
        obs.validate_record(rec)
    back = SchedTrace.from_records(t1.to_records(channel=chan))
    assert lines(back) == lines(t1)
    assert back.discipline == disc
    assert back.staleness_hist() == t1.staleness_hist()


def test_sched_event_stream_counters_sum_to_cum_bytes(setup):
    """The new per-stream int64 counters decompose the pre-existing
    cum_bytes exactly, event by event."""
    task, batch_fn = setup
    fed = _fed(sched=SchedConfig(discipline="async"))
    _, trace = _run_sched(task, batch_fn, fed, 4)
    for ev in trace.events:
        assert (ev.cum_uplink_bytes + ev.cum_downlink_bytes
                + ev.cum_hessian_uplink_bytes
                + ev.cum_hessian_downlink_bytes) == ev.cum_bytes


def test_from_records_requires_summary():
    with pytest.raises(ValueError, match="sched_summary"):
        SchedTrace.from_records([])


# ------------------------------------------------------------- spans
def test_span_log_records():
    log = obs.SpanLog()
    with log.span("pack"):
        pass
    with log.span("dispatch", virtual_s=12.5):
        pass
    recs = log.records()
    assert [r["name"] for r in recs] == ["pack", "dispatch"]
    assert recs[1]["virtual_s"] == 12.5
    for r in recs:
        obs.validate_record(r)
        assert r["wall_s"] >= 0.0


# ------------------------------------------------- trace contexts
def _traced_fed(disc, trace=True):
    return _fed(obs=ObsConfig(probes=True, trace=trace),
                sched=SchedConfig(discipline=disc))


@pytest.mark.parametrize("disc", ["sync", "semisync", "async"])
def test_trace_ids_roundtrip_byte_identical(setup, disc):
    """trace_id survives to_records/from_records byte-identically, ids
    are contiguous 1-based in dispatch order, and every event's folded
    trace_ids point at a real dispatch."""
    task, batch_fn = setup
    _, trace = _run_sched(task, batch_fn, _traced_fed(disc), 3)
    assert trace.dispatches, "tracing on but no dispatch contexts"
    tids = [d.trace_id for d in trace.dispatches]
    assert tids == list(range(1, len(tids) + 1))
    recs = trace.to_records()
    for r in recs:
        obs.validate_record(r)
    lines = [json.dumps(r, sort_keys=True) for r in recs]
    back = SchedTrace.from_records(recs)
    assert [json.dumps(r, sort_keys=True)
            for r in back.to_records()] == lines
    by_id = {d.trace_id for d in trace.dispatches}
    for ev in trace.events:
        assert ev.trace_ids and set(ev.trace_ids) <= by_id


def test_tracing_off_keeps_v1_serialization(setup):
    """With tracing off the record stream is byte-compatible with v1
    consumers: no sched_dispatch records, no trace_ids field."""
    task, batch_fn = setup
    _, trace = _run_sched(task, batch_fn,
                          _traced_fed("semisync", trace=False), 3)
    assert not trace.dispatches
    for r in trace.to_records():
        assert r["record"] != "sched_dispatch"
        assert "trace_ids" not in r


@pytest.mark.parametrize("disc", ["semisync", "async"])
def test_tracing_on_state_bitwise_identical(setup, disc):
    """The acceptance bar: trace contexts are pure host bookkeeping —
    the scheduler's state trajectory and event stream are bitwise
    unchanged, tracing on vs off."""
    task, batch_fn = setup
    s_off, t_off = _run_sched(task, batch_fn,
                              _traced_fed(disc, trace=False), 3)
    s_on, t_on = _run_sched(task, batch_fn,
                            _traced_fed(disc, trace=True), 3)
    for a, b in zip(jax.tree.leaves(s_off), jax.tree.leaves(s_on)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    off_lines = [json.dumps(r, sort_keys=True)
                 for r in t_off.to_records()]
    on_recs = [r for r in t_on.to_records()
               if r["record"] != "sched_dispatch"]
    for r in on_recs:
        r.pop("trace_ids", None)
    assert [json.dumps(r, sort_keys=True) for r in on_recs] == off_lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="rewrite the committed schema golden")
    if ap.parse_args().regen:
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            json.dump(obs.describe(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN}")
