"""Device-residency safety net: packed between-round params, buffer
donation (incl. the scheduler's end-to-end dispatch donation), bf16
and per-buffer fp8 resident state (`CommConfig.moment_dtype` /
`hessian_dtype`), chunked large-group dispatch
(`SchedConfig.dispatch_chunk`), and FSWB v1->v2 checkpoint compat.

Three claims are pinned here (docs/architecture.md "Memory layout:
the life of a round"):

* **Packed == tree.** A round over packed-resident state
  (`FedEngine.pack_state`) computes the SAME per-coordinate op
  sequence as the tree-resident round for fp32 models — bitwise under
  op-by-op execution (see tests/test_flat_engine.py for why jit
  bitwise-ness is only claimed where program structure cannot change
  XLA:CPU's per-fusion FMA contraction).
* **Donation changes nothing but ownership.** The donated round
  (`FedEngine.round_fn(donate=True)`) is bitwise identical to the
  undonated one; the donated input state is actually invalidated
  (the donation contract is real, not advisory).
* **bf16 resident state degrades gracefully.** Kernels and refs agree
  on the bf16 load/store path, and an engine round with
  ``state_dtype="bfloat16"`` stays close to its fp32 twin for one
  round (one bf16 store rounding per buffer).

Plus the wire-format compat satellite: v1 headers/manifests load
under the v2 build (`state_dtype` defaults to float32), and the
checkpoint shims round-trip packed state exactly.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.comm import flat as cflat
from repro.configs.base import CommConfig, FedConfig
from repro.core.fed import FedEngine
from repro.data import synthetic as syn
from repro.models.small import MLPTask


# ---------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def task_data():
    key = jax.random.PRNGKey(0)
    x, y = syn.make_image_data(key, 512, "mnist", noise=1.3)
    part = syn.dirichlet_partition(jax.random.fold_in(key, 1), y, 4,
                                   alpha=0.5)
    tr, _ = syn.train_test_split(part)
    batches = syn.client_batches(jax.random.fold_in(key, 2), x, y, tr, 16)
    return MLPTask(), batches, key


def _engine(task, comm=None, opt="fed_sophia", **kw):
    fed = FedConfig(num_clients=4, local_iters=2, optimizer=opt, lr=0.02,
                    tau=2, total_rounds=8, comm=comm or CommConfig(), **kw)
    return FedEngine(task, fed)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


def _assert_bitwise(a, b, msg=""):
    for la, lb in zip(_leaves(a), _leaves(b)):
        np.testing.assert_array_equal(la, lb, err_msg=msg)


# ------------------------------------------------- packed == tree rounds
PACKED_MATRIX = [
    ("direct", CommConfig(), "fed_sophia"),
    ("uplink-int8", CommConfig(compressor="int8"), "fed_sophia"),
    ("ef-topk", CommConfig(compressor="topk"), "fedavg"),
    ("bidir", CommConfig(compressor="int8", downlink_compressor="int8",
                         hessian_compressor="int4"), "fed_sophia"),
    ("fedadam", CommConfig(compressor="int8"), "fedadam"),
]


@pytest.mark.parametrize("name,comm,opt", PACKED_MATRIX,
                         ids=[c[0] for c in PACKED_MATRIX])
def test_packed_round_matches_tree_round(task_data, name, comm, opt):
    """Two rounds over packed-resident state, unpacked at the end, are
    BITWISE the tree-resident rounds (op-by-op execution)."""
    task, batches, key = task_data
    e = _engine(task, comm, opt)
    s_tree = e.init(key)
    s_pack = e.pack_state(e.init(key))
    assert e.params_packed(s_pack["params"])
    assert not e.params_packed(s_tree["params"])
    with jax.disable_jit():
        for r in range(2):
            rng = jax.random.fold_in(key, 10 + r)
            s_tree, m_tree = e.round(s_tree, batches, rng)
            s_pack, m_pack = e.round(s_pack, batches, rng)
    _assert_bitwise(s_tree, e.unpack_state(s_pack), name)
    np.testing.assert_array_equal(np.asarray(m_tree["loss"]),
                                  np.asarray(m_pack["loss"]))


def test_packed_round_matches_tree_round_jit_fedavg(task_data):
    """Under jit, bitwise where program structure cannot change FMA
    contraction (fedavg — no EMA chain; see test_flat_engine)."""
    task, batches, key = task_data
    e = _engine(task, CommConfig(compressor="int8"), "fedavg")
    s_tree = e.init(key)
    s_pack = e.pack_state(e.init(key))
    rng = jax.random.fold_in(key, 11)
    s_tree, _ = jax.jit(e.round)(s_tree, batches, rng)
    s_pack, _ = jax.jit(e.round)(s_pack, batches, rng)
    _assert_bitwise(s_tree["params"], e.unpack_params(s_pack))


def test_pack_unpack_state_roundtrip(task_data):
    task, _, key = task_data
    e = _engine(task, CommConfig(compressor="topk"), "fedadam")
    state = e.init(key)
    rt = e.comm_runtime(state["params"])
    packed = e.pack_state(state)
    # idempotent both ways
    assert e.pack_state(packed)["params"] is packed["params"]
    back = e.unpack_state(packed)
    _assert_bitwise(state, back)
    assert e.num_params(packed) == e.num_params(state) == rt.spec.total


# ------------------------------------- client-batched comm step (tentpole)
BATCHED_STEP_MATRIX = [
    ("int8-pallas", CommConfig(compressor="int8", use_pallas=True,
                               downlink_compressor="int8",
                               hessian_compressor="int4")),
    ("int8", CommConfig(compressor="int8")),
    ("ef-topk", CommConfig(compressor="topk")),
    ("int8-pallas-bf16", CommConfig(compressor="int8", use_pallas=True,
                                    state_dtype="bfloat16")),
    # per-buffer fp8 residency: bf16 params, e4m3 moments, e5m2
    # hessian — gathered rows reach the kernels in their storage
    # dtypes and upcast in-VMEM
    ("int8-pallas-fp8", CommConfig(compressor="int8", use_pallas=True,
                                   state_dtype="bfloat16",
                                   moment_dtype="float8_e4m3fn",
                                   hessian_dtype="float8_e5m2")),
]


@pytest.mark.parametrize("name,comm", BATCHED_STEP_MATRIX,
                         ids=[c[0] for c in BATCHED_STEP_MATRIX])
def test_comm_client_step_batched_matches_vmap(task_data, name, comm):
    """`FedEngine.comm_client_step_batched` (ONE client-batched pass:
    batched kernels, scan-of-vmap local training) is BITWISE the
    vmapped per-client `comm_client_step` — fp32 and bf16 resident
    rows (gathered rows flow to the kernels un-upcast), with and
    without the Pallas lowering (op-by-op execution)."""
    from repro.comm import downlink as cdown
    task, batches, key = task_data
    e = _engine(task, comm)
    state = e.pack_state(e.init(key))
    params = state["params"]
    rt = e.runtime_for(params)
    theta = params.astype(jnp.float32)
    theta_dn = (cflat.repack(theta, rt.spec, rt.spec_dn)
                if rt.dn_on else None)
    round_idx = jnp.asarray(0, jnp.int32)
    rng = jax.random.fold_in(key, 21)
    crngs = jax.vmap(lambda i: jax.random.fold_in(rng, i))(jnp.arange(4))
    opts = state.get("client_opt")
    efs = state.get("comm_ef")
    dnms = state.get(cdown.MODEL_KEY)
    dnefs = state.get(cdown.EF_KEY)

    def run_batched():
        return e.comm_client_step_batched(
            rt, theta, theta_dn, round_idx, 0.02, opts, efs, dnms,
            dnefs, batches, crngs)

    def run_looped():
        return jax.vmap(
            lambda opt, ef_i, dnm, dnef, b, r: e.comm_client_step(
                rt, theta, theta_dn, round_idx, 0.02, opt, ef_i, dnm,
                dnef, b, r))(opts, efs, dnms, dnefs, batches, crngs)

    if comm.use_pallas:
        # Pallas interpret mode cannot run under disable_jit (its
        # interpreter lowers through jit); the jitted comparison is
        # what the engine actually executes anyway
        batched, looped = jax.jit(run_batched)(), jax.jit(run_looped)()
    else:
        with jax.disable_jit():
            batched, looped = run_batched(), run_looped()
    _assert_bitwise(batched, looped, name)


# ------------------------------------------------------ donation contract
def test_donated_round_bitwise_and_invalidating(task_data):
    """Donated vs undonated jitted rounds are bitwise identical, under
    either residency — and donation actually invalidates the caller's
    state (the documented contract, not a no-op)."""
    task, batches, key = task_data
    e = _engine(task, CommConfig(compressor="int8"))
    rng = jax.random.fold_in(key, 12)
    for packed in (False, True):
        mk = ((lambda: e.pack_state(e.init(key))) if packed
              else (lambda: e.init(key)))
        s_u, m_u = e.round_fn(donate=False)(mk(), batches, rng)
        donated_in = mk()
        s_d, m_d = e.round_fn(donate=True)(donated_in, batches, rng)
        _assert_bitwise(s_u, s_d, f"packed={packed}")
        np.testing.assert_array_equal(np.asarray(m_u["loss"]),
                                      np.asarray(m_d["loss"]))
        # chaining donated rounds (the real training loop) works
        s_d, _ = e.round_fn(donate=True)(s_d, batches,
                                         jax.random.fold_in(rng, 1))
        if jax.default_backend() in ("cpu", "tpu", "gpu"):
            with pytest.raises(Exception):
                np.asarray(jax.tree.leaves(donated_in)[0]) + 0


def test_donated_scheduler_matches_undonated(task_data):
    """The event-loop scheduler with donate=True reproduces the
    undonated run event-for-event (packed state)."""
    from repro.configs.base import SchedConfig
    from repro.sched import VirtualScheduler
    task, batches, key = task_data
    comm = CommConfig(compressor="int8")
    sched = SchedConfig(discipline="semisync", buffer_size=2,
                        latency_profile="straggler")
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fed_sophia",
                    lr=0.02, tau=2, comm=comm, sched=sched)
    e = FedEngine(task, fed)
    batch_fn = lambda v: batches
    s1, t1 = VirtualScheduler(e, batch_fn).run(
        e.init(key), 3, jax.random.fold_in(key, 13))
    # donate=True consumes batch_fn results (dispatch-side donation),
    # so the donated run must hand over fresh copies per version
    fresh_fn = lambda v: jax.tree.map(jnp.copy, batches)
    s2, t2 = VirtualScheduler(e, fresh_fn, donate=True).run(
        e.pack_state(e.init(key)), 3, jax.random.fold_in(key, 13))
    assert [ev.loss for ev in t1.events] == [ev.loss for ev in t2.events]
    _assert_bitwise(s1["params"], e.unpack_params(s2))


# --------------------------------------------- chunked large-C dispatch
@pytest.mark.parametrize("chunk", [2, 3, 5],
                         ids=["even", "ragged-tail", "over-group"])
def test_chunked_dispatch_bitwise(task_data, chunk):
    """`SchedConfig.dispatch_chunk` runs an N-client dispatch group as
    a lax-driven sequence of fixed-size chunks through the batched
    comm step — bitwise equal to the unchunked ONE-launch path, with
    an even split, a ragged tail (N % chunk != 0), and a chunk larger
    than the group (the unchunked fast path)."""
    from repro.comm import downlink as cdown
    from repro.configs.base import SchedConfig
    task, batches, key = task_data
    comm = CommConfig(compressor="int8")
    base = _engine(task, comm)
    chunked = _engine(task, comm, sched=SchedConfig(dispatch_chunk=chunk))
    state = base.pack_state(base.init(key))
    params = state["params"]
    rt = base.runtime_for(params)
    theta = params.astype(jnp.float32)
    theta_dn = (cflat.repack(theta, rt.spec, rt.spec_dn)
                if rt.dn_on else None)
    round_idx = jnp.asarray(0, jnp.int32)
    rng = jax.random.fold_in(key, 31)
    crngs = jax.vmap(lambda i: jax.random.fold_in(rng, i))(jnp.arange(4))
    args = (theta, theta_dn, round_idx, 0.02,
            state.get("client_opt"), state.get("comm_ef"),
            state.get(cdown.MODEL_KEY), state.get(cdown.EF_KEY),
            batches, crngs)
    flat = jax.jit(
        lambda *a: base.comm_client_step_batched(rt, *a))(*args)
    split = jax.jit(
        lambda *a: chunked.comm_client_step_batched(rt, *a))(*args)
    _assert_bitwise(flat, split, f"chunk={chunk}")


# ------------------------------------------------------ bf16 resident state
def test_bf16_kernel_paths_match_refs():
    """The kernels' bf16 load/store path agrees with the dtype-aware
    refs (identical casts -> allclose at bf16 resolution), and fp32
    stays bit-identical to the pre-dtype behaviour."""
    from repro.kernels import ref
    from repro.kernels.quantize import (broadcast_roundtrip_flat,
                                        quant_roundtrip_flat,
                                        uplink_roundtrip_flat)
    from repro.kernels.sophia_update import sophia_update_flat
    from repro.kernels.stale_accum import stale_accum_flat
    key = jax.random.PRNGKey(7)
    R, C = 8, 256
    mk = lambda i, dt: jax.random.normal(
        jax.random.fold_in(key, i), (R, C)).astype(dt)
    for dt in (jnp.float32, jnp.bfloat16):
        x, start, ef = mk(0, dt), mk(1, dt), mk(2, dt)
        noise = jax.random.uniform(jax.random.fold_in(key, 3), (R, C))
        scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1,
                        keepdims=True) / 127
        got = quant_roundtrip_flat(x, noise, scale, qmax=127)
        want = ref.quant_roundtrip_ref(x, noise, scale, qmax=127)
        assert got.dtype == dt
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # XLA:CPU contracts d - q*scale into an FMA per fusion (the
        # caveat documented in tests/test_flat_engine.py): the residual
        # may differ by one ulp of the compared dtype
        ulp = 1e-6 if dt == jnp.float32 else 1e-2
        gu = uplink_roundtrip_flat(x, start, ef, noise, scale, qmax=127)
        wu = ref.uplink_roundtrip_ref(x, start, ef, noise, scale,
                                      qmax=127)
        for g, w in zip(gu, wu):
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w, np.float32),
                                       rtol=ulp, atol=ulp)
        gb = broadcast_roundtrip_flat(x, start, ef, noise, scale,
                                      qmax=127)
        wb = ref.broadcast_roundtrip_ref(x, start, ef, noise, scale,
                                         qmax=127)
        for g, w in zip(gb, wb):
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w, np.float32),
                                       rtol=ulp, atol=ulp)
        gs = sophia_update_flat(x, start, jnp.abs(ef), mk(4, dt),
                                jnp.abs(mk(5, dt)), True, 1e-2,
                                beta1=0.9, beta2=0.95, rho=0.04,
                                eps=1e-12, weight_decay=1e-4)
        ws = ref.sophia_update_ref(x, start, jnp.abs(ef), mk(4, dt),
                                   jnp.abs(mk(5, dt)), True, lr=1e-2,
                                   beta1=0.9, beta2=0.95, rho=0.04,
                                   eps=1e-12, weight_decay=1e-4)
        for g, w in zip(gs, ws):
            assert g.dtype == dt
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w, np.float32),
                                       rtol=ulp, atol=ulp)
        wires = jnp.stack([mk(i, dt) for i in (0, 1, 2)])
        wts = jnp.asarray([1.0, 0.5, 0.25], jnp.float32)
        ga = stale_accum_flat(wires, wts, 1.0 / jnp.sum(wts))
        wa = ref.stale_accum_ref(wires, wts, 1.0 / jnp.sum(wts))
        assert ga.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(ga), np.asarray(wa),
                                   rtol=1e-6, atol=1e-6)


def test_bf16_round_tolerance_and_dtypes(task_data):
    """One bf16-resident round stays within bf16 rounding of its fp32
    twin, and the resident dtypes survive the round (the scatter-back
    downcast)."""
    task, batches, key = task_data
    rng = jax.random.fold_in(key, 14)
    e32 = _engine(task, CommConfig(compressor="int8"))
    e16 = _engine(task, CommConfig(compressor="int8",
                                   state_dtype="bfloat16"))
    s32, m32 = jax.jit(e32.round)(e32.pack_state(e32.init(key)),
                                  batches, rng)
    s16, m16 = jax.jit(e16.round)(e16.pack_state(e16.init(key)),
                                  batches, rng)
    assert s16["params"].dtype == jnp.bfloat16
    assert s16["client_opt"].m.dtype == jnp.bfloat16
    assert s16["client_opt"].h.dtype == jnp.bfloat16
    # the inputs agree to bf16 rounding (~3 decimal digits); one round
    # of fp32 compute keeps the outputs within that neighbourhood
    np.testing.assert_allclose(
        np.asarray(s16["params"], np.float32), np.asarray(s32["params"]),
        rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(float(m16["loss"]), float(m32["loss"]),
                               rtol=2e-2)
    # multi-round stability: losses stay finite
    s, fn = s16, e16.round_fn(donate=True)
    for r in range(3):
        s, m = fn(s, batches, jax.random.fold_in(rng, r))
    assert np.isfinite(float(m["loss"]))


# ------------------------------------------------- fp8 resident state
def test_fp8_round_tolerance_and_dtypes(task_data):
    """One round with per-buffer fp8 residency (bf16 params, e4m3
    moments, e5m2 hessian) stays in the neighbourhood of its fp32
    twin, the per-buffer dtypes survive the round's scatter-back
    downcast, and donated rounds stay finite.  The band is wider than
    bf16's: the fp8 m/h enter the next round through the Sophia
    preconditioner, but the clipped step (|step| <= rho) bounds how
    far one round can drift."""
    task, batches, key = task_data
    rng = jax.random.fold_in(key, 16)
    e32 = _engine(task, CommConfig(compressor="int8"))
    e8 = _engine(task, CommConfig(compressor="int8",
                                  state_dtype="bfloat16",
                                  moment_dtype="float8_e4m3fn",
                                  hessian_dtype="float8_e5m2"))
    s32, m32 = jax.jit(e32.round)(e32.pack_state(e32.init(key)),
                                  batches, rng)
    s8, m8 = jax.jit(e8.round)(e8.pack_state(e8.init(key)),
                               batches, rng)
    assert s8["params"].dtype == jnp.bfloat16
    assert s8["client_opt"].m.dtype == jnp.float8_e4m3fn
    assert s8["client_opt"].h.dtype == jnp.float8_e5m2
    # params start bf16-rounded and move by lr-scaled clipped steps;
    # the fp8 EMAs only perturb the step direction
    np.testing.assert_allclose(
        np.asarray(s8["params"], np.float32), np.asarray(s32["params"]),
        rtol=1e-1, atol=1e-1)
    np.testing.assert_allclose(float(m8["loss"]), float(m32["loss"]),
                               rtol=1e-1)
    # multi-round stability under donation: dtypes hold, losses finite
    s, fn = s8, e8.round_fn(donate=True)
    for r in range(3):
        s, m = fn(s, batches, jax.random.fold_in(rng, r))
    assert s["client_opt"].m.dtype == jnp.float8_e4m3fn
    assert s["client_opt"].h.dtype == jnp.float8_e5m2
    assert np.isfinite(float(m["loss"]))


# ------------------------------------------- FSWB v2 header + v1 compat
def test_header_v2_roundtrip_and_v1_decode():
    h = cflat.Header(compressor="int8", total=1000, quant_block=128,
                     state_dtype="bfloat16")
    assert h.version == cflat.WIRE_VERSION == 2
    got = cflat.Header.unpack(h.pack())
    assert got == h
    # a v1 header (reserved flags byte == 0) decodes as float32
    v1 = cflat.Header(compressor="int8", total=1000, quant_block=128,
                      version=1)
    got1 = cflat.Header.unpack(v1.pack())
    assert got1.version == 1 and got1.state_dtype == "float32"
    # v1 cannot carry a non-float32 state dtype
    with pytest.raises(ValueError, match="v1"):
        cflat.Header(compressor="int8", total=1, quant_block=1,
                     version=1, state_dtype="bfloat16").pack()
    # corrupt v1 flags byte rejected
    raw = bytearray(v1.pack())
    raw[7] = 0x01
    with pytest.raises(ValueError, match="reserved"):
        cflat.Header.unpack(bytes(raw))
    # v2 reserved high nibble rejected too
    raw = bytearray(h.pack())
    raw[7] |= 0x10
    with pytest.raises(ValueError, match="reserved"):
        cflat.Header.unpack(bytes(raw))
    # unknown version rejected
    raw = bytearray(h.pack())
    raw[4] = 9
    with pytest.raises(ValueError, match="version"):
        cflat.Header.unpack(bytes(raw))
    # fp8 flags-byte ids (2 = e4m3, 3 = e5m2) round-trip under v2
    for dt in ("float8_e4m3fn", "float8_e5m2"):
        h8 = cflat.Header(compressor="int8", total=1000, quant_block=128,
                          state_dtype=dt)
        got8 = cflat.Header.unpack(h8.pack())
        assert got8 == h8 and got8.state_dtype == dt
    # v1 cannot carry an fp8 state dtype either
    with pytest.raises(ValueError, match="v1"):
        cflat.Header(compressor="int8", total=1, quant_block=1,
                     version=1, state_dtype="float8_e5m2").pack()
    # a raw v1 payload whose flags byte claims an fp8 id is corrupt
    # (v1 builds never wrote one) — rejected, not decoded
    for flags in (0x02, 0x03):
        raw = bytearray(v1.pack())
        raw[7] = flags
        with pytest.raises(ValueError, match="reserved"):
            cflat.Header.unpack(bytes(raw))
    # v2 low-nibble ids beyond the registry rejected
    raw = bytearray(h.pack())
    raw[7] = (raw[7] & 0xF0) | 0x04
    with pytest.raises(ValueError, match="state-dtype"):
        cflat.Header.unpack(bytes(raw))


def _strip_to_v1(headers):
    """A manifest as a v1 build would have written it: version 1, no
    state_dtype field."""
    out = {}
    for k, d in headers.items():
        d = {f: v for f, v in d.items() if f != "state_dtype"}
        d["version"] = 1
        out[k] = d
    return out


def test_check_headers_accepts_v1_manifest(task_data):
    task, _, key = task_data
    e = _engine(task, CommConfig(compressor="int8",
                                 downlink_compressor="int8",
                                 hessian_compressor="int4"))
    params = e.init(key)["params"]
    current = e.wire_headers(params)
    assert all(d["version"] == 2 for d in current.values())
    # a checkpoint written by the v1 build loads under the v2 build
    cflat.check_headers(_strip_to_v1(current), current)
    # ...but layout mismatches still fail loudly
    bad = _strip_to_v1(current)
    bad["uplink"]["quant_block"] = 999
    with pytest.raises(ValueError, match="quant_block"):
        cflat.check_headers(bad, current)
    # state_dtype is a runtime residency choice, not a layout field:
    # resuming an fp32 checkpoint under bf16 residency is supported
    # (checkpoints store the dtype-agnostic pytree; resident buffers
    # are rebuilt on restore)
    e16 = _engine(task, CommConfig(compressor="int8",
                                   downlink_compressor="int8",
                                   hessian_compressor="int4",
                                   state_dtype="bfloat16"))
    cur16 = e16.wire_headers(params)
    cflat.check_headers(_strip_to_v1(current), cur16)
    cflat.check_headers(current, cur16)


def test_resume_v1_checkpoint_under_v2(tmp_path, task_data):
    """End-to-end --resume proof: a checkpoint whose manifest carries
    v1 wire headers restores under the v2 build through the exact
    train.py resume path (load_manifest -> check_headers -> restore ->
    restore_params -> pack_state)."""
    task, batches, key = task_data
    e = _engine(task, CommConfig(compressor="int8"))
    state = e.init(key)
    rng = jax.random.fold_in(key, 15)
    state, _ = jax.jit(e.round)(state, batches, rng)
    path = os.fspath(tmp_path / "ck")
    # write the checkpoint as the v1 build would have
    ckpt.save(path, state["params"], step=1,
              extra={"wire": _strip_to_v1(e.wire_headers(
                  state["params"]))})
    # the v2 build's resume path
    e2 = _engine(task, CommConfig(compressor="int8"))
    s2 = e2.init(key)
    manifest = ckpt.load_manifest(path)
    cflat.check_headers(manifest["extra"]["wire"],
                        e2.wire_headers(s2["params"]))
    restored = ckpt.restore(path, s2["params"])
    s2 = e2.restore_params(s2, restored)
    _assert_bitwise(s2["params"], state["params"])
    # and the restored run continues packed + donated
    s2 = e2.pack_state(s2)
    s2, m = e2.round_fn(donate=True)(s2, batches,
                                     jax.random.fold_in(rng, 1))
    assert np.isfinite(float(m["loss"]))


# ------------------------------------------------- launch bundle (api.py)
def test_build_train_packed_state_bundle_compiles():
    """`launch.api.build_train(packed_state=True)` ships a state struct
    whose params (and wire-layout client state) are packed, with the
    flat sharding rule, and the bundle lowers + compiles."""
    from repro.launch import api
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    b = api.build_train("minicpm-2b", mesh, reduced=True, local_iters=2,
                        packed_state=True)
    state = b.args[0]
    assert state["params"].ndim == 2          # packed, not a pytree
    assert b.meta["packed_state"]
    compiled = jax.jit(b.fn, in_shardings=b.in_shardings,
                       out_shardings=b.out_shardings).lower(
                           *b.args).compile()
    assert compiled is not None


# ------------------------------------------------------- checkpoint shims
def test_ckpt_packed_shims_roundtrip(tmp_path, task_data):
    task, _, key = task_data
    e = _engine(task, CommConfig())
    state = e.pack_state(e.init(key))
    spec = e.runtime_for(state["params"]).spec
    path = os.fspath(tmp_path / "ck")
    ckpt.save_packed(path, state["params"], spec, step=3,
                     extra={"wire": e.wire_headers(state["params"])})
    # on-disk format is the pytree (residency-agnostic)
    tree = ckpt.restore(path, e.unpack_params(state))
    _assert_bitwise(tree, e.unpack_params(state))
    # restore straight back into wire layout, either dtype
    back32 = ckpt.restore_packed(path, spec)
    np.testing.assert_array_equal(np.asarray(back32),
                                  np.asarray(state["params"]))
    back16 = ckpt.restore_packed(path, spec, dtype=jnp.bfloat16)
    assert back16.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(back16, np.float32), np.asarray(state["params"]),
        rtol=1e-2, atol=1e-2)
    assert ckpt.load_manifest(path)["step"] == 3
