"""GSPMD donation-aliasing dryrun (fast, tier-1): the packed-resident
train round, compiled on a simulated 8-device mesh with the state
donated, must alias every per-device resident shard in place —
partitioning the (rows, cols) wire buffer and the (C, rows, cols)
client stacks may not silently reintroduce a per-round state copy.
Runs in a subprocess: the placeholder device count must be set before
jax initializes."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=300):
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO)


def test_donation_survives_partitioning():
    r = _run(["--arch", "minicpm-2b", "--check-donation",
              "--local-iters", "2", "--out-dir", ""])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "state_copy_B=0" in r.stdout, r.stdout + r.stderr
