import jax
import pytest

# NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
# run on the single real CPU device. Only launch/dryrun.py forces 512
# placeholder devices (in its own process).

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
