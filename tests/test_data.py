"""Non-IID partitioner tests (repro.data.partition).

The statistical pin: the Dirichlet label-skew concentration statistic
(`label_concentration`, mean max class share per client) is MONOTONE
in 1/alpha — large alpha gives near-IID clients, small alpha
concentrates each class on few clients.  Everything else is exact:
determinism per seed, apportionment sums, minimum-sample floors, the
fixed-geometry `equalize` contract, quantity skew and feature shift.
"""
import numpy as np
import pytest

from repro.data import partition as dpart

SEED = 11


@pytest.fixture(scope="module")
def labels():
    rng = np.random.default_rng(0)
    return rng.integers(0, 10, size=4096)


# ----------------------------------------------- dirichlet label skew
def test_dirichlet_partition_exact_cover(labels):
    """The partition is an exact disjoint cover of the pool."""
    parts = dpart.dirichlet_label_partition(labels, 8, alpha=0.5,
                                            seed=SEED)
    allidx = np.concatenate(parts)
    assert allidx.size == labels.size
    assert np.array_equal(np.sort(allidx), np.arange(labels.size))


def test_dirichlet_partition_deterministic(labels):
    """Same seed -> identical partition; different seed differs."""
    a = dpart.dirichlet_label_partition(labels, 8, alpha=0.1, seed=SEED)
    b = dpart.dirichlet_label_partition(labels, 8, alpha=0.1, seed=SEED)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
    c = dpart.dirichlet_label_partition(labels, 8, alpha=0.1,
                                        seed=SEED + 1)
    assert any(not np.array_equal(pa, pc) for pa, pc in zip(a, c))


def test_dirichlet_partition_min_per_client(labels):
    """Every client owns at least min_per_client samples even at the
    pathological alpha."""
    parts = dpart.dirichlet_label_partition(labels, 16, alpha=0.05,
                                            seed=SEED, min_per_client=8)
    assert all(p.size >= 8 for p in parts)


def test_dirichlet_concentration_monotone_in_inverse_alpha(labels):
    """The statistical pin: smaller alpha -> larger mean max class
    share, averaged over seeds; alpha=100 sits near the IID floor."""
    def stat(alpha):
        vals = []
        for s in range(5):
            parts = dpart.dirichlet_label_partition(labels, 8, alpha,
                                                    seed=s)
            vals.append(dpart.label_concentration(
                dpart.label_marginals(labels, parts, 10)))
        return float(np.mean(vals))

    iid, mid, skew = stat(100.0), stat(1.0), stat(0.1)
    assert iid < mid < skew
    assert iid < 0.2          # near the 1/num_classes = 0.1 floor
    assert skew > 0.45        # strong per-client class concentration


def test_dirichlet_rejects_bad_alpha(labels):
    with pytest.raises(ValueError):
        dpart.dirichlet_label_partition(labels, 4, alpha=0.0, seed=0)


# -------------------------------------------------------- apportionment
def test_apportion_exact_sum_and_proportionality():
    rng = np.random.default_rng(1)
    for _ in range(20):
        shares = rng.dirichlet(np.ones(7))
        total = int(rng.integers(1, 5000))
        counts = dpart._apportion(rng, total, shares)
        assert counts.sum() == total
        assert np.all(np.abs(counts - shares * total) < 1.0 + 1e-9)


# -------------------------------------------------------- quantity skew
def test_quantity_skew_sizes_sum_and_minimum():
    sizes = dpart.quantity_skew_sizes(1000, 8, alpha=0.3, seed=SEED,
                                      min_per_client=5)
    assert sizes.sum() == 1000
    assert np.all(sizes >= 5)
    np.testing.assert_array_equal(
        sizes, dpart.quantity_skew_sizes(1000, 8, alpha=0.3, seed=SEED,
                                         min_per_client=5))
    with pytest.raises(ValueError):
        dpart.quantity_skew_sizes(3, 4, alpha=0.3, seed=0)


def test_subsample_respects_sizes_and_ownership(labels):
    parts = dpart.dirichlet_label_partition(labels, 4, alpha=0.5,
                                            seed=SEED)
    sizes = np.array([10, 20, 30, 10 ** 9])
    out = dpart.subsample(parts, sizes, seed=SEED)
    for p, s, o in zip(parts, sizes, out):
        assert o.size == min(int(s), p.size)
        assert np.isin(o, p).all()
        assert np.unique(o).size == o.size  # without replacement


# ------------------------------------------------------------- equalize
def test_equalize_fixed_geometry_and_ownership(labels):
    parts = dpart.dirichlet_label_partition(labels, 8, alpha=0.1,
                                            seed=SEED)
    out = dpart.equalize(parts, 64, seed=SEED)
    assert out.shape == (8, 64) and out.dtype == np.int32
    for i, p in enumerate(parts):
        assert np.isin(out[i], p).all()
        if p.size >= 64:
            assert np.unique(out[i]).size == 64
    with pytest.raises(ValueError):
        dpart.equalize([np.zeros((0,), np.int64)], 4, seed=0)


# -------------------------------------------------------- feature shift
def test_feature_shift_identity_and_determinism():
    rng = np.random.default_rng(2)
    x = rng.standard_normal((4, 32, 5)).astype(np.float32)
    np.testing.assert_array_equal(dpart.feature_shift(x, 0.0, SEED), x)
    a = dpart.feature_shift(x, 0.5, SEED)
    b = dpart.feature_shift(x, 0.5, SEED)
    np.testing.assert_array_equal(a, b)
    assert a.shape == x.shape and a.dtype == np.float32
    assert not np.array_equal(a, x)
    # per-client affine: x == 0 maps to the client bias everywhere
    z = dpart.feature_shift(np.zeros_like(x), 0.5, SEED)
    for c in range(4):
        assert np.unique(z[c]).size == 1


# ------------------------------------------------------------ marginals
def test_label_marginals_rows_are_distributions(labels):
    parts = dpart.dirichlet_label_partition(labels, 8, alpha=0.2,
                                            seed=SEED)
    m = dpart.label_marginals(labels, parts, 10)
    assert m.shape == (8, 10)
    np.testing.assert_allclose(m.sum(axis=1), 1.0, rtol=1e-9)
    assert 0.1 <= dpart.label_concentration(m) <= 1.0
