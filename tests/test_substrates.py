"""Data pipeline, checkpointing, schedules and energy-model tests."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs.base import FedConfig
from repro.core.schedules import lr_at_round
from repro.data import synthetic as syn
from repro.metrics import energy


# ------------------------------------------------------------------- data
def test_image_data_shapes_and_determinism():
    key = jax.random.PRNGKey(0)
    x1, y1 = syn.make_image_data(key, 256, "mnist")
    x2, y2 = syn.make_image_data(key, 256, "mnist")
    assert x1.shape == (256, 28, 28, 1) and y1.shape == (256,)
    np.testing.assert_array_equal(x1, x2)
    xf, _ = syn.make_image_data(key, 256, "fmnist")
    assert not np.allclose(x1, xf)


def test_dirichlet_partition_is_non_iid():
    key = jax.random.PRNGKey(0)
    _, y = syn.make_image_data(key, 4096, "mnist")
    part = syn.dirichlet_partition(jax.random.PRNGKey(1), y, 8, alpha=0.1)
    assert part.shape == (8, 512)
    # low alpha -> per-client class histograms far from uniform
    hists = np.stack([np.bincount(np.asarray(y)[p], minlength=10)
                      for p in part])
    frac_max = (hists.max(1) / hists.sum(1))
    assert frac_max.mean() > 0.3   # uniform would be 0.1


def test_train_test_split_disjoint():
    key = jax.random.PRNGKey(0)
    _, y = syn.make_image_data(key, 1024, "mnist")
    part = syn.dirichlet_partition(jax.random.PRNGKey(1), y, 4)
    tr, te = syn.train_test_split(part)
    assert tr.shape[1] + te.shape[1] == part.shape[1]
    for i in range(4):
        assert set(tr[i]) | set(te[i]) <= set(part[i])


def test_client_batches_shapes():
    key = jax.random.PRNGKey(0)
    x, y = syn.make_image_data(key, 1024, "mnist")
    part = syn.dirichlet_partition(jax.random.PRNGKey(1), y, 4)
    b = syn.client_batches(jax.random.PRNGKey(2), x, y, part, 16)
    assert b["x"].shape == (4, 16, 28, 28, 1)
    assert b["y"].shape == (4, 16)


def test_token_batch():
    b = syn.make_token_batch(jax.random.PRNGKey(0), 2, 4, 32, 100)
    assert b["tokens"].shape == (2, 4, 32)
    assert b["labels"].shape == (2, 4, 32)
    assert int(b["tokens"].max()) < 100
    # markov structure: labels are mostly perm[tokens]
    match = (b["labels"][..., :-1] != b["tokens"][..., 1:]).mean()
    assert match < 1e-6


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    path = str(tmp_path / "ck")
    ckpt.save(path, tree, step=42, extra={"note": "hi"})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored = ckpt.restore(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    man = ckpt.load_manifest(path)
    assert man["step"] == 42 and man["extra"]["note"] == "hi"


# -------------------------------------------------------------- schedules
def test_schedules():
    for sched in ("const", "cosine", "wsd"):
        fed = FedConfig(lr=1e-2, schedule=sched, total_rounds=100,
                        warmup_rounds=10)
        lrs = [float(lr_at_round(fed, r)) for r in range(100)]
        assert lrs[0] < 1e-2 + 1e-9            # warmup active
        assert all(l >= 0 for l in lrs)
        assert max(lrs) <= 1e-2 + 1e-9
    fed = FedConfig(lr=1e-2, schedule="wsd", total_rounds=100,
                    decay_frac=0.2)
    stable = float(lr_at_round(fed, 50))
    assert abs(stable - 1e-2) < 1e-9           # stable phase at base lr
    assert float(lr_at_round(fed, 99)) < stable  # decay tail


# ------------------------------------------------------------------ energy
def test_shannon_rate_paper_constants():
    ch = energy.ChannelModel()
    # R = B log2(1 + Pt/(d*B*N0)) with paper constants
    expected = 2e6 * math.log2(1 + 0.1 / (50.0 * 2e6 * 1e-9))
    assert abs(ch.rate() - expected) / expected < 1e-12


def test_round_energy_decomposition():
    out = energy.round_energy(num_params=1_000_000, flops_per_iter=1e9,
                              local_iters=10, hessian_iters=1)
    assert out["total_J"] == pytest.approx(out["compute_J"] + out["comm_J"])
    assert out["comm_J"] > 0 and out["compute_J"] > 0
    # communication energy dominates for small models over weak links
    assert out["comm_J"] > out["compute_J"]


def test_second_order_fewer_rounds_lower_comm():
    """The paper's Table II mechanism: fewer rounds => less comm energy."""
    n = 100_000
    e_sophia = energy.round_energy(n, 1e9, 10, hessian_iters=2)
    e_fedavg = energy.round_energy(n, 1e9, 10)
    # per round Sophia costs slightly more compute...
    assert e_sophia["compute_J"] > e_fedavg["compute_J"]
    # ...but at 30 vs 100 rounds total it wins overall
    assert 30 * e_sophia["total_J"] < 100 * e_fedavg["total_J"]
