"""Dry-run smoke test: the launch machinery must lower+compile reduced
configs on an 8-device placeholder mesh, in a subprocess (device-count env
must be set before jax initializes)."""
import json
import os
import subprocess
import sys

import pytest

# subprocess mesh lower+compile per arch: heavy; run with `pytest -m slow`
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=600):
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO)


@pytest.mark.parametrize("arch,shape", [
    ("minicpm-2b", "train_4k"),
    ("qwen3-moe-235b-a22b", "train_4k"),       # sequential + MoE
    ("gemma2-9b", "prefill_32k"),
    ("deepseek-v2-lite-16b", "decode_32k"),    # MLA cache
    ("xlstm-1.3b", "long_500k"),               # recurrent decode
])
def test_dryrun_reduced_small_mesh(arch, shape, tmp_path):
    r = _run(["--arch", arch, "--shape", shape, "--small-mesh", "--reduced",
              "--local-iters", "2", "--out-dir", str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    files = os.listdir(tmp_path)
    assert len(files) == 1
    rec = json.load(open(tmp_path / files[0]))
    assert rec["status"] == "ok", rec.get("error")
    assert rec["hlo_flops_per_dev"] > 0
    assert rec["roofline"]["bottleneck"] in ("compute", "memory",
                                             "collective")


def test_dryrun_multipod_reduced(tmp_path):
    r = _run(["--arch", "recurrentgemma-2b", "--shape", "train_4k",
              "--small-mesh", "--multi-pod", "--reduced",
              "--local-iters", "2", "--out-dir", str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(tmp_path / os.listdir(tmp_path)[0]))
    assert rec["status"] == "ok", rec.get("error")
    assert rec["mesh_shape"].get("pod") == 2


def test_dryrun_skip_rules(tmp_path):
    r = _run(["--arch", "hubert-xlarge", "--shape", "decode_32k",
              "--small-mesh", "--reduced", "--out-dir", str(tmp_path)])
    assert r.returncode == 0
    assert "skipped" in r.stdout
