"""End-to-end behaviour tests for the Fed-Sophia system."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import FedConfig
from repro.core.fed import FedEngine
from repro.data import synthetic as syn
from repro.models.small import MLPTask

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_fed_sophia_reaches_target_accuracy():
    """The paper's end-to-end claim: non-IID federated training converges
    to a useful model with Fed-Sophia."""
    key = jax.random.PRNGKey(0)
    x, y = syn.make_image_data(key, 8192, "mnist", noise=1.3)
    part = syn.dirichlet_partition(jax.random.fold_in(key, 1), y, 8,
                                   alpha=0.5)
    tr, te = syn.train_test_split(part)
    task = MLPTask(hidden=64)
    fed = FedConfig(num_clients=8, local_iters=10, optimizer="fed_sophia",
                    lr=0.02, tau=5, total_rounds=15)
    engine = FedEngine(task, fed)
    state = engine.init(jax.random.fold_in(key, 2))
    rnd = jax.jit(engine.round)
    for r in range(15):
        batches = syn.client_batches(jax.random.fold_in(key, 100 + r),
                                     x, y, tr, 64)
        state, _ = rnd(state, batches, jax.random.fold_in(key, 1000 + r))
    teb = syn.client_batches(jax.random.fold_in(key, 3), x, y, te, 128)
    acc = float(jnp.mean(jax.vmap(
        lambda b: task.accuracy(state["params"], b))(teb)))
    assert acc >= 0.75, f"test accuracy {acc} below the paper's target"


def test_fed_sophia_pallas_path_trains():
    """use_pallas=True (fused kernel, interpret on CPU) must match the
    training behaviour of the reference path."""
    key = jax.random.PRNGKey(0)
    x, y = syn.make_image_data(key, 2048, "mnist", noise=1.0)
    part = syn.dirichlet_partition(jax.random.fold_in(key, 1), y, 4)
    tr, _ = syn.train_test_split(part)
    task = MLPTask(hidden=32)
    outs = {}
    for use_pallas in (False, True):
        fed = FedConfig(num_clients=4, local_iters=2,
                        optimizer="fed_sophia", lr=0.02, tau=2,
                        use_pallas=use_pallas)
        engine = FedEngine(task, fed)
        state = engine.init(jax.random.fold_in(key, 2))
        batches = syn.client_batches(jax.random.fold_in(key, 3), x, y,
                                     tr, 32)
        state, metrics = engine.round(state, batches,
                                      jax.random.fold_in(key, 4))
        outs[use_pallas] = state["params"]
        assert jnp.isfinite(metrics["loss"])
    for a, b in zip(jax.tree.leaves(outs[False]),
                    jax.tree.leaves(outs[True])):
        assert jnp.allclose(a, b, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("script,args", [
    ("examples/quickstart.py", []),
    ("examples/fed_llm_train.py", ["--small"]),
    ("examples/serve_batched.py", ["--arch", "chatglm3-6b", "--batch", "2",
                                   "--prompt-len", "8", "--gen", "4"]),
])
def test_examples_run(script, args):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, os.path.join(REPO, script)] + args,
                       capture_output=True, text=True, timeout=1200,
                       env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
