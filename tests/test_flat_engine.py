"""Bit-exactness safety net of the flat-resident round engine.

The engine keeps all client-visible state in the packed (rows, cols)
wire layout end-to-end (docs/architecture.md "Memory layout"); that
refactor is only safe because the flat round computes the SAME
per-coordinate op sequence as the historical pytree engine for fp32
models — the flattening order is frozen and every hot-path op is
elementwise.  This file carries a faithful copy of the pre-refactor
tree-resident round (`TreeRoundRef`, built from the public
`repro.core.sophia` / `repro.core.gnb` / `repro.comm` pieces) and pins
the live engine against it across the

    {fed_sophia, fedavg} x {parallel, sequential}
        x {direct, uplink-only, bidir, EF-on}

matrix, including the persistent Sophia m/h state (compared row-by-row
through `flat.pack`).

One backend caveat bounds what "bitwise" can mean: XLA:CPU contracts
mul+add chains into FMAs *per fused loop*, so two structurally
different programs with identical math can disagree in the last ulp of
an EMA (verified: materializing the intermediate makes the difference
vanish).  Sophia's m-EMA feeds a division by near-zero curvature, so
under jit that single ulp is chaotically amplified across rounds.  The
matrix is therefore pinned BITWISE under op-by-op execution
(`jax.disable_jit`, where no cross-op fusion exists) — and bitwise
*under jit* wherever program structure cannot change contraction: the
fedavg matrix (no EMA chain) and the fused-Pallas fed_sophia path (the
kernel is one opaque unit in both engines).
"""
import contextlib
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import downlink as cdown, flat as cflat
from repro.kernels import tuning as ktuning
from repro.comm.compressors import (make_compressor, make_stream_compressor,
                                    participation_indices,
                                    wants_error_feedback)
from repro.configs.base import CommConfig, FedConfig
from repro.core import sophia
from repro.core.fed import PARTICIPATION_SALT, FedEngine
from repro.core.gnb import gnb_estimate
from repro.core.schedules import lr_at_round
from repro.data import synthetic as syn
from repro.models.small import MLPTask
from repro.utils.tree import tree_sub, tree_zeros_like


def _vg(loss_fn, params, batch, rng=None):
    return jax.value_and_grad(loss_fn)(params, batch, rng)


class TreeRoundRef:
    """The pre-flat-refactor `FedEngine.round`, pytree-resident.

    Trimmed to the optimizers/paths the equivalence matrix covers
    (fed_sophia with persistent state, fedavg); rng folds, scan/vmap
    structure and op order mirror the historical engine exactly.
    """

    def __init__(self, task, fed: FedConfig):
        self.task = task
        self.fed = fed

    # ------------------------------------------------------------- state
    def init(self, key):
        fed = self.fed
        params = self.task.init(key)
        state = {"params": params, "round": jnp.zeros((), jnp.int32)}
        comm = fed.comm
        if fed.optimizer == "fed_sophia" and fed.persistent_client_state:
            opt = sophia.init_state(params)
            state["client_opt"] = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (fed.num_clients,) + x.shape).copy(), opt)
        if wants_error_feedback(comm):
            spec = cflat.flat_spec(params, cols=comm.quant_block)
            state["comm_ef"] = jnp.zeros(
                (fed.num_clients, spec.rows, spec.cols), jnp.float32)
        if comm.downlink_enabled:
            spec_dn = cflat.flat_spec(
                params, cols=comm.stream("downlink").quant_block)
            state.update(cdown.init_state(
                comm, spec_dn, cflat.pack(params, spec_dn),
                fed.num_clients))
        return state

    # ---------------------------------------------------- local training
    def _local_sophia(self, params, opt, batch, round_idx, rng, lr):
        fed = self.fed
        task = self.task
        round_mode = fed.hessian_every_unit == "round"
        if round_mode:
            do_h_round = (round_idx % fed.tau) == 0
            h_hat_round = jax.lax.cond(
                do_h_round,
                lambda: gnb_estimate(task, params, batch,
                                     jax.random.fold_in(rng, 0x7FFFFFFF),
                                     vg_fn=_vg),
                lambda: tree_zeros_like(params))

        def step(carry, j):
            p, st = carry
            loss, grads = _vg(task.loss, p, batch, None)
            if round_mode:
                do_h = do_h_round & (j == 0)
                h_hat = h_hat_round
            else:
                t = round_idx * fed.local_iters + j
                do_h = (t % fed.tau) == 0
                rng_j = jax.random.fold_in(rng, j)
                h_hat = jax.lax.cond(
                    do_h,
                    lambda: gnb_estimate(task, p, batch, rng_j, vg_fn=_vg),
                    lambda: tree_zeros_like(p))
            p, st = sophia.sophia_step(
                p, grads, st, h_hat, do_h,
                lr=lr, beta1=fed.beta1, beta2=fed.beta2, rho=fed.rho,
                eps=fed.eps, weight_decay=fed.weight_decay,
                use_pallas=fed.use_pallas)
            return (p, st), loss

        (params, opt), losses = jax.lax.scan(
            step, (params, opt), jnp.arange(fed.local_iters))
        return params, opt, jnp.mean(losses)

    def _local_sgd(self, params, batch, lr):
        def step(p, j):
            loss, grads = _vg(self.task.loss, p, batch, None)
            p = jax.tree.map(lambda t, g: (t - lr * g).astype(t.dtype),
                             p, grads)
            return p, loss
        params, losses = jax.lax.scan(
            step, params, jnp.arange(self.fed.local_iters))
        return params, jnp.mean(losses)

    def _local_update(self, params, opt, batch, crng, round_idx, lr):
        fed = self.fed
        if fed.optimizer == "fed_sophia":
            if opt is None:
                opt = sophia.init_state(params)
            p, o, loss = self._local_sophia(params, opt, batch, round_idx,
                                            crng, lr)
            return p, (o if fed.persistent_client_state else None), loss
        p, loss = self._local_sgd(params, batch, lr)
        return p, None, loss

    # ------------------------------------------------------------- round
    def uses_direct_path(self):
        comm = self.fed.comm
        C = self.fed.num_clients
        return (comm.lossless and comm.num_participants(C) == C
                and not comm.multi_stream)

    def round(self, state, batches, rng):
        fed = self.fed
        round_idx = state["round"]
        lr = lr_at_round(fed, round_idx)
        client_rngs = jax.vmap(lambda i: jax.random.fold_in(rng, i))(
            jnp.arange(fed.num_clients))
        if self.uses_direct_path():
            state, loss = self._round_direct(state, batches, client_rngs,
                                             round_idx, lr)
        else:
            state, loss = self._round_comm(state, batches, client_rngs,
                                           round_idx, lr, rng)
        return {**state, "round": round_idx + 1}, {"loss": loss}

    def _round_direct(self, state, batches, client_rngs, round_idx, lr):
        fed = self.fed
        params = state["params"]
        C = fed.num_clients
        stateful = (fed.optimizer == "fed_sophia"
                    and fed.persistent_client_state)
        opts = state.get("client_opt") if stateful else None
        if fed.strategy == "parallel":
            if stateful:
                new_p, new_opt, losses = jax.vmap(
                    lambda o, b, r: self._local_update(
                        params, o, b, r, round_idx, lr)
                )(opts, batches, client_rngs)
            else:
                new_p, new_opt, losses = jax.vmap(
                    lambda b, r: self._local_update(
                        params, None, b, r, round_idx, lr)
                )(batches, client_rngs)
            agg = jax.tree.map(lambda x: jnp.mean(x, axis=0), new_p)
        else:
            def scan_body(acc, xs):
                opt, batch, crng = xs
                p_i, opt_i, loss = self._local_update(
                    params, opt, batch, crng, round_idx, lr)
                acc = jax.tree.map(lambda a, x: a + x / C, acc, p_i)
                return acc, (opt_i, loss)
            agg, (new_opt, losses) = jax.lax.scan(
                scan_body, tree_zeros_like(params),
                (opts, batches, client_rngs))
            agg = jax.tree.map(lambda a, p: a.astype(p.dtype), agg, params)
        state = {**state, "params": agg}
        if stateful:
            state = {**state, "client_opt": new_opt}
        return state, jnp.mean(losses)

    def _comm_client_step(self, rt, params, packed_theta, round_idx, lr,
                          opt, ef_i, dnm_i, dnef_i, batch, crng):
        spec_dn, comp_dn, spec_h, comp_h = rt["dn"] + rt["h"]
        spec, comp = rt["up"]
        if comp_dn is not None:
            dnm_i, dnef_i = cdown.broadcast(
                comp_dn, jax.random.fold_in(crng, 0xD0),
                packed_theta, dnm_i, dnef_i)
            p_start = cflat.unpack(dnm_i, spec_dn)
        else:
            p_start = params
        p_i, opt_i, loss = self._local_update(
            p_start, opt, batch, crng, round_idx, lr)
        delta = cflat.pack(tree_sub(p_i, p_start), spec)
        if ef_i is not None:
            delta = delta + ef_i
        xhat, stat = comp.roundtrip(jax.random.fold_in(crng, 0xC0), delta)
        ef_new = None if ef_i is None else delta - xhat
        h_hat = h_stat = None
        if comp_h is not None:
            h_hat, h_stat = comp_h.roundtrip(
                jax.random.fold_in(crng, 0x4E),
                cflat.pack(opt_i.h, spec_h))
        return (xhat, stat, ef_new, opt_i, loss,
                dnm_i if comp_dn is not None else None, dnef_i,
                h_hat, h_stat)

    def _runtime(self, params):
        comm = self.fed.comm
        spec = cflat.flat_spec(params, cols=comm.quant_block)
        rt = {"up": (spec, make_compressor(comm, spec)),
              "dn": (None, None), "h": (None, None)}
        if comm.downlink_enabled:
            s = cflat.flat_spec(
                params, cols=comm.stream("downlink").quant_block)
            rt["dn"] = (s, make_stream_compressor(comm, "downlink", s))
        if comm.hessian_enabled:
            s = cflat.flat_spec(
                params, cols=comm.stream("hessian").quant_block)
            rt["h"] = (s, make_stream_compressor(comm, "hessian", s))
        return rt

    def _round_comm(self, state, batches, client_rngs, round_idx, lr, rng):
        fed = self.fed
        comm = fed.comm
        params = state["params"]
        C = fed.num_clients
        S = comm.num_participants(C)
        rt = self._runtime(params)
        spec, comp = rt["up"]
        spec_dn, comp_dn = rt["dn"]
        spec_h, comp_h = rt["h"]
        dn_on, h_on = comp_dn is not None, comp_h is not None
        packed_theta = cflat.pack(params, spec_dn) if dn_on else None
        idx = participation_indices(
            jax.random.fold_in(rng, PARTICIPATION_SALT + comm.seed), C, S)
        stateful = (fed.optimizer == "fed_sophia"
                    and fed.persistent_client_state)
        opts = state.get("client_opt") if stateful else None
        ef = state.get("comm_ef")
        dn_model = state.get(cdown.MODEL_KEY)
        dn_ef = state.get(cdown.EF_KEY)

        def take(tree):
            return (None if tree is None
                    else jax.tree.map(lambda x: x[idx], tree))

        opts_g, ef_g = take(opts), take(ef)
        dnm_g, dnef_g = take(dn_model), take(dn_ef)
        batches_g, rngs_g = take(batches), client_rngs[idx]
        client = functools.partial(self._comm_client_step, rt, params,
                                   packed_theta, round_idx, lr)

        if fed.strategy == "parallel":
            (wires, stats, ef_new_g, opt_new_g, losses, dnm_new_g,
             dnef_new_g, h_hat_g, h_stat_g) = jax.vmap(client)(
                opts_g, ef_g, dnm_g, dnef_g, batches_g, rngs_g)
            agg_flat = jnp.sum(wires, axis=0) / S
            wstat = jnp.sum(stats) / S
            if dn_on:
                dn_mean = jnp.sum(dnm_new_g, axis=0) / S
            if h_on:
                h_agg = jnp.sum(h_hat_g, axis=0) / S
                h_wstat = jnp.sum(h_stat_g) / S
        else:
            def scan_body(acc, xs):
                opt, ef_i, dnm_i, dnef_i, batch, crng = xs
                (wire, stat, ef_i_new, opt_i, loss, dnm_new, dnef_new,
                 h_hat, h_stat) = client(opt, ef_i, dnm_i, dnef_i,
                                         batch, crng)
                acc = {**acc, "w": acc["w"] + wire / S,
                       "s": acc["s"] + stat / S}
                if dn_on:
                    acc = {**acc, "dn": acc["dn"] + dnm_new / S}
                if h_on:
                    acc = {**acc, "h": acc["h"] + h_hat / S,
                           "hs": acc["hs"] + h_stat / S}
                return acc, (ef_i_new, opt_i, loss, dnm_new, dnef_new)
            acc0 = {"w": jnp.zeros((spec.rows, spec.cols), jnp.float32),
                    "s": jnp.zeros((), jnp.float32)}
            if dn_on:
                acc0["dn"] = jnp.zeros(
                    (spec_dn.rows, spec_dn.cols), jnp.float32)
            if h_on:
                acc0["h"] = jnp.zeros(
                    (spec_h.rows, spec_h.cols), jnp.float32)
                acc0["hs"] = jnp.zeros((), jnp.float32)
            acc, (ef_new_g, opt_new_g, losses, dnm_new_g, dnef_new_g) = \
                jax.lax.scan(scan_body, acc0,
                             (opts_g, ef_g, dnm_g, dnef_g,
                              batches_g, rngs_g))
            agg_flat, wstat = acc["w"], acc["s"]
            if dn_on:
                dn_mean = acc["dn"]
            if h_on:
                h_agg, h_wstat = acc["h"], acc["hs"]

        agg_flat = comp.server_combine(agg_flat, wstat)
        if dn_on:
            corr = dn_mean - packed_theta
            if spec_dn.cols != spec.cols:
                corr = cflat.repack(corr, spec_dn, spec)
            agg_flat = agg_flat + corr
        agg_delta = cflat.unpack(agg_flat, spec)
        agg = jax.tree.map(lambda p, d: (p + d).astype(p.dtype),
                           params, agg_delta)
        state = {**state, "params": agg}
        if stateful:
            new_opts = jax.tree.map(
                lambda full, g: full.at[idx].set(g), opts, opt_new_g)
            if h_on:
                h_down, _ = comp_h.roundtrip(
                    jax.random.fold_in(rng, 0x4D),
                    comp_h.server_combine(h_agg, h_wstat))
                h_avg = cflat.unpack(h_down, spec_h)
                new_h = jax.tree.map(
                    lambda full, v: full.at[idx].set(jnp.broadcast_to(
                        v[None], (S,) + v.shape).astype(full.dtype)),
                    new_opts.h, h_avg)
                new_opts = new_opts._replace(h=new_h)
            state = {**state, "client_opt": new_opts}
        if ef is not None:
            state = {**state, "comm_ef": ef.at[idx].set(ef_new_g)}
        if dn_model is not None:
            state = {**state, cdown.MODEL_KEY:
                     dn_model.at[idx].set(dnm_new_g)}
        if dn_ef is not None:
            state = {**state, cdown.EF_KEY: dn_ef.at[idx].set(dnef_new_g)}
        return state, jnp.mean(losses)


# --------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    x, y = syn.make_image_data(key, 512, "mnist", noise=1.0)
    part = syn.dirichlet_partition(jax.random.PRNGKey(1), y, 4, alpha=0.5)
    tr, _ = syn.train_test_split(part)
    task = MLPTask(hidden=16)
    batches = syn.client_batches(key, x, y, tr, 16)
    return task, batches


COMMS = {
    "direct": lambda opt: CommConfig(),
    "uplink-int8": lambda opt: CommConfig(compressor="int8"),
    # bidir: compressed broadcast everywhere; the hessian stream only
    # exists for persistent fed_sophia
    "bidir": lambda opt: CommConfig(
        compressor="int8", downlink_compressor="int8",
        hessian_compressor="int4" if opt == "fed_sophia" else "off"),
    # EF-on (topk is biased -> "auto" materialises residuals), plus
    # partial participation to cover the gather/scatter path
    "ef-topk": lambda opt: CommConfig(compressor="topk", topk_ratio=0.05,
                                      participation=0.5),
}


def _run_both(task, fed, batches, rounds=2, jit=True):
    """(flat engine state, ref state, per-round losses) after ``rounds``.

    jit=False runs both engines op-by-op (`jax.disable_jit`): every
    primitive executes as its own kernel, so XLA's fusion-dependent
    FMA contraction cannot differ between the two program structures
    and bitwise comparison is meaningful.
    """
    eng = FedEngine(task, fed)
    ref = TreeRoundRef(task, fed)
    ctx = jax.disable_jit() if not jit else contextlib.nullcontext()
    with ctx:
        s_eng = eng.init(jax.random.PRNGKey(2))
        s_ref = ref.init(jax.random.PRNGKey(2))
        rf_eng = jax.jit(eng.round) if jit else eng.round
        rf_ref = jax.jit(ref.round) if jit else ref.round
        losses = []
        for r in range(rounds):
            rng = jax.random.PRNGKey(100 + r)
            s_eng, m_eng = rf_eng(s_eng, batches, rng)
            s_ref, m_ref = rf_ref(s_ref, batches, rng)
            losses.append((float(m_eng["loss"]), float(m_ref["loss"])))
    return eng, s_eng, s_ref, losses


def _assert_state_bit_identical(eng, s_eng, s_ref, atol=None):
    """Bitwise by default; ``atol`` switches to absolute-tolerance
    comparison (for the jitted configs where XLA's per-fusion FMA
    contraction forbids strict equality — see module docstring)."""
    def check(a, b):
        if atol is None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a, np.float64),
                                       np.asarray(b, np.float64),
                                       rtol=0, atol=atol)

    for a, b in zip(jax.tree.leaves(s_eng["params"]),
                    jax.tree.leaves(s_ref["params"])):
        check(a, b)
    # wire-layout comm state carries identical keys in both engines
    for k in ("comm_ef", cdown.MODEL_KEY, cdown.EF_KEY):
        assert (k in s_eng) == (k in s_ref)
        if k in s_eng:
            check(s_eng[k], s_ref[k])
    # persistent Sophia state: the engine stores (C, rows, cols) wire
    # buffers, the reference per-client pytrees — pack the reference
    # rows into the same layout and compare
    assert ("client_opt" in s_eng) == ("client_opt" in s_ref)
    if "client_opt" in s_eng:
        spec = eng.comm_runtime(s_eng["params"]).spec
        for flat_buf, tree_full in ((s_eng["client_opt"].m,
                                     s_ref["client_opt"].m),
                                    (s_eng["client_opt"].h,
                                     s_ref["client_opt"].h)):
            C = flat_buf.shape[0]
            for i in range(C):
                row_tree = jax.tree.map(lambda x, i=i: x[i], tree_full)
                check(flat_buf[i], cflat.pack(row_tree, spec))


@pytest.mark.parametrize("comm_name", sorted(COMMS))
@pytest.mark.parametrize("strategy", ["parallel", "sequential"])
def test_flat_round_bit_identical_jit_fedavg(setup, strategy, comm_name):
    """fedavg's local update has no EMA mul+add chain, so even jitted
    programs contract identically: bitwise under jit across the whole
    comm matrix, both strategies."""
    task, batches = setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fedavg",
                    strategy=strategy, lr=0.01, tau=2,
                    comm=COMMS[comm_name]("fedavg"))
    eng, s_eng, s_ref, losses = _run_both(task, fed, batches)
    for le, lr_ in losses:
        assert le == lr_, (comm_name, losses)
    _assert_state_bit_identical(eng, s_eng, s_ref)


SOPHIA_MATRIX = [
    pytest.param("parallel", "direct", id="parallel-direct"),
    pytest.param("parallel", "uplink-int8", id="parallel-uplink-int8"),
    pytest.param("parallel", "bidir", id="parallel-bidir",
                 marks=pytest.mark.slow),
    pytest.param("parallel", "ef-topk", id="parallel-ef-topk",
                 marks=pytest.mark.slow),
    pytest.param("sequential", "direct", id="sequential-direct",
                 marks=pytest.mark.slow),
    pytest.param("sequential", "uplink-int8", id="sequential-uplink-int8",
                 marks=pytest.mark.slow),
    pytest.param("sequential", "bidir", id="sequential-bidir",
                 marks=pytest.mark.slow),
    pytest.param("sequential", "ef-topk", id="sequential-ef-topk",
                 marks=pytest.mark.slow),
]


@pytest.mark.parametrize("strategy,comm_name", SOPHIA_MATRIX)
def test_flat_round_bit_identical_opbyop_sophia(setup, strategy,
                                                comm_name):
    """fed_sophia across the matrix, op-by-op: bitwise equal including
    the packed m/h state (the heavy off-diagonal combos carry the slow
    marker; two representatives stay in tier-1)."""
    task, batches = setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fed_sophia",
                    strategy=strategy, lr=0.01, tau=2,
                    comm=COMMS[comm_name]("fed_sophia"))
    eng, s_eng, s_ref, losses = _run_both(task, fed, batches, jit=False)
    for le, lr_ in losses:
        assert le == lr_, (comm_name, losses)
    _assert_state_bit_identical(eng, s_eng, s_ref)


def test_flat_round_jit_sophia_close(setup):
    """Jitted fed_sophia sanity net: XLA's per-fusion FMA contraction
    seeds last-ulp EMA differences that the near-zero-curvature divide
    amplifies, so jit-vs-jit across different program structures is
    allclose, not bitwise (op-by-op IS bitwise — see above)."""
    task, batches = setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fed_sophia",
                    strategy="parallel", lr=0.01, tau=2,
                    comm=CommConfig(compressor="int8"))
    eng, s_eng, s_ref, losses = _run_both(task, fed, batches)
    for le, lr_ in losses:
        assert le == pytest.approx(lr_, rel=1e-5), losses
    for a, b in zip(jax.tree.leaves(s_eng["params"]),
                    jax.tree.leaves(s_ref["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


@pytest.fixture
def default_kernel_geometry(monkeypatch, tmp_path):
    """Force the safe default launch geometry (one client per grid
    step) regardless of the committed tuning table.  Kernel VALUES are
    block-invariant (pinned per kernel x dtype x geometry by
    tests/test_kernel_conformance.py), but in interpret mode a
    different grid restructures the surrounding jitted program enough
    for XLA:CPU's per-fusion FMA contraction to seed a last-ulp
    difference vs the tree reference (the module-docstring caveat) —
    so the flat-vs-tree BITWISE pin runs on the fixed historical
    geometry."""
    monkeypatch.setattr(ktuning, "TUNING_PATH",
                        str(tmp_path / "absent.json"))
    ktuning.load_tuning.cache_clear()
    yield
    ktuning.load_tuning.cache_clear()


def test_flat_round_bit_identical_jit_pallas_kernels(
        setup, default_kernel_geometry):
    """The fused-kernel path: flat-resident state feeds the Sophia and
    quantize kernels directly; the reference packs/unpacks around the
    same kernels per iteration (the historical behaviour).  The kernel
    is one opaque unit in both programs, so this is bitwise even under
    jit — the production path carries the strongest guarantee.  Pinned
    on the default launch geometry (see `default_kernel_geometry`);
    the tuned batched geometry's value-equivalence is pinned by the
    kernel conformance suite and
    tests/test_residency.py::test_comm_client_step_batched_matches_vmap."""
    task, batches = setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fed_sophia",
                    strategy="parallel", lr=0.01, tau=2, use_pallas=True,
                    comm=CommConfig(compressor="int8", use_pallas=True))
    eng, s_eng, s_ref, losses = _run_both(task, fed, batches)
    for le, lr_ in losses:
        assert le == lr_, losses
    _assert_state_bit_identical(eng, s_eng, s_ref)


def test_flat_round_jit_pallas_fused_uplink_ef_close(setup):
    """Forced client EF for int8 routes the engine through the fused
    uplink encode kernel (`uplink_roundtrip_flat`).  The extra EF
    plumbing changes the surrounding XLA program enough for per-fusion
    contraction to seed a last-ulp difference in the per-row quant
    scale (observed max |diff| ~1e-10; interpret-mode Pallas cannot
    run under jax.disable_jit in this jax build, so the op-by-op
    escape hatch is unavailable here) — pinned allclose at 1e-8, three
    orders tighter than any training-relevant scale."""
    task, batches = setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fed_sophia",
                    strategy="parallel", lr=0.01, tau=2, use_pallas=True,
                    comm=CommConfig(compressor="int8", use_pallas=True,
                                    error_feedback=True))
    eng, s_eng, s_ref, losses = _run_both(task, fed, batches)
    for le, lr_ in losses:
        assert le == pytest.approx(lr_, rel=1e-6), losses
    assert "comm_ef" in s_eng and "comm_ef" in s_ref
    _assert_state_bit_identical(eng, s_eng, s_ref, atol=1e-8)


@pytest.mark.parametrize("kw", [
    {"hessian_every_unit": "round", "tau": 1},
    {"persistent_client_state": False},
], ids=["round-mode", "stateless"])
def test_flat_round_bit_identical_opbyop_variants(setup, kw):
    """hessian_every_unit='round' (hoisted GNB) and the stateless
    fed_sophia variant also ride the flat path bit-exactly."""
    task, batches = setup
    base = dict(num_clients=4, local_iters=2, optimizer="fed_sophia",
                lr=0.01, tau=2, comm=CommConfig(compressor="int8"))
    base.update(kw)
    fed = FedConfig(**base)
    eng, s_eng, s_ref, losses = _run_both(task, fed, batches, rounds=1,
                                          jit=False)
    for le, lr_ in losses:
        assert le == lr_, (kw, losses)
    _assert_state_bit_identical(eng, s_eng, s_ref)
