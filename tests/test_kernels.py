"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import sophia_fused_step
from repro.kernels.ref import sophia_update_ref, uplink_roundtrip_ref
from repro.kernels.sophia_update import sophia_update_flat

HP = dict(beta1=0.9, beta2=0.95, rho=0.04, eps=1e-12, weight_decay=1e-4)


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


@pytest.mark.parametrize("shape", [(8, 128), (256, 1024), (300, 1024),
                                   (1, 1024), (257, 1000), (1024, 2048)])
@pytest.mark.parametrize("do_h", [0.0, 1.0])
def test_flat_kernel_matches_ref_shapes(shape, do_h):
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    ks = jax.random.split(key, 5)
    theta = _rand(ks[0], shape)
    m = _rand(ks[1], shape, scale=0.1)
    h = jnp.abs(_rand(ks[2], shape, scale=0.01))
    g = _rand(ks[3], shape, scale=0.5)
    hh = jnp.abs(_rand(ks[4], shape, scale=0.02))
    lr = 3e-3
    out = sophia_update_flat(theta, m, h, g, hh, do_h, lr, interpret=True,
                             **HP)
    ref = sophia_update_ref(theta, m, h, g, hh, do_h, lr=lr, **HP)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pytree_fused_step_matches_core(dtype):
    from repro.core import sophia as core_sophia
    key = jax.random.PRNGKey(0)
    params = {"a": _rand(key, (33, 65), dtype),
              "b": {"c": _rand(jax.random.fold_in(key, 1), (7,), dtype),
                    "d": _rand(jax.random.fold_in(key, 2), (4, 5, 6), dtype)}}
    grads = jax.tree.map(lambda x: 0.1 * jnp.ones_like(x), params)
    st = core_sophia.init_state(params)
    h_hat = jax.tree.map(lambda x: 0.2 * jnp.ones_like(x), params)
    kwargs = dict(lr=1e-2, **HP)
    ref_p, ref_st = core_sophia.sophia_step(
        params, grads, st, h_hat, jnp.asarray(True), use_pallas=False,
        **kwargs)
    out_p, out_st = core_sophia.sophia_step(
        params, grads, st, h_hat, jnp.asarray(True), use_pallas=True,
        **kwargs)
    tol = dict(rtol=2e-2, atol=1e-3) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-7)
    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(out_p)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **tol)
    for a, b in zip(jax.tree.leaves(ref_st.h), jax.tree.leaves(out_st.h)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **tol)


def test_fused_step_traced_lr_and_flag():
    """lr and do_h arrive as tracers from the schedule/round index."""
    key = jax.random.PRNGKey(1)
    params = {"w": _rand(key, (130, 70))}
    grads = jax.tree.map(jnp.ones_like, params)
    h_hat = jax.tree.map(jnp.ones_like, params)

    @jax.jit
    def step(p, lr, do_h):
        return sophia_fused_step(p, jax.tree.map(jnp.zeros_like, p),
                                 jax.tree.map(jnp.zeros_like, p),
                                 grads, h_hat, do_h, lr=lr, **HP)

    p1, m1, h1 = step(params, jnp.asarray(1e-2), jnp.asarray(1.0))
    p2, m2, h2 = step(params, jnp.asarray(0.0), jnp.asarray(0.0))
    assert not np.allclose(p1["w"], params["w"])
    np.testing.assert_allclose(p2["w"], params["w"])   # lr=0 -> no-op
    np.testing.assert_allclose(h2["w"], 0.0)           # do_h=0 -> h frozen


@pytest.mark.parametrize("qmax", [127, 7])
@pytest.mark.parametrize("with_ef", [False, True])
def test_uplink_roundtrip_kernel_matches_ref(qmax, with_ef):
    """Fused uplink encode (delta + EF + quant round-trip + residual)
    == pure-jnp reference, and consistent with the unfused
    quantize-a-precomputed-delta path."""
    from repro.kernels.quantize import (quant_roundtrip_flat,
                                        uplink_roundtrip_flat)
    key = jax.random.PRNGKey(3)
    theta = _rand(key, (300, 130))
    start = theta + 0.05 * _rand(jax.random.fold_in(key, 1), (300, 130))
    ef = (0.01 * _rand(jax.random.fold_in(key, 2), (300, 130))
          if with_ef else jnp.zeros_like(theta))
    delta = theta - start + ef
    u = jax.random.uniform(jax.random.fold_in(key, 3), delta.shape)
    scale = jnp.max(jnp.abs(delta), axis=1, keepdims=True) / qmax
    xhat, resid = uplink_roundtrip_flat(theta, start, ef, u, scale,
                                        qmax=qmax, interpret=True)
    ref_x, ref_r = uplink_roundtrip_ref(theta, start, ef, u, scale,
                                        qmax=qmax)
    np.testing.assert_allclose(np.asarray(xhat), np.asarray(ref_x),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(resid), np.asarray(ref_r),
                               rtol=1e-6, atol=1e-7)
    unfused = quant_roundtrip_flat(delta, u, scale, qmax=qmax,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(xhat), np.asarray(unfused),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(xhat + resid), np.asarray(delta),
                               rtol=1e-6, atol=1e-6)
