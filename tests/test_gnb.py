"""GNB estimator tests (Alg. 2): h_hat = B * g_hat ⊙ g_hat, and its
statistical relationship to the exact Gauss-Newton diagonal."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gnb import gnb_estimate
from repro.models.small import MLPTask


def test_gnb_is_b_ghat_sq():
    task = MLPTask(hidden=16)
    key = jax.random.PRNGKey(0)
    p = task.init(key)
    batch = {"x": jax.random.normal(key, (32, 28, 28, 1)),
             "y": jax.random.randint(key, (32,), 0, 10)}
    rng = jax.random.PRNGKey(7)
    h = gnb_estimate(task, p, batch, rng)
    g = jax.grad(task.sampled_loss)(p, batch, rng)
    for hl, gl in zip(jax.tree.leaves(h), jax.tree.leaves(g)):
        np.testing.assert_allclose(hl, 32 * gl * gl, rtol=1e-5)
    # PSD: diagonal estimate is non-negative everywhere
    assert all(jnp.all(l >= 0) for l in jax.tree.leaves(h))


def test_gnb_expectation_matches_gn_diagonal_logreg():
    """For softmax regression the exact GN diagonal is computable:
    diag = sum_b x_b^2 (p_b - p_b^2) per class. E[GNB] over label draws
    should approach it (Bartlett identity, up to 1/B sampling factor)."""
    key = jax.random.PRNGKey(1)
    d, k, B = 5, 3, 4
    W = 0.3 * jax.random.normal(key, (d, k))
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, d))

    def loss(W, y):
        logits = x @ W
        lse = jax.nn.logsumexp(logits, axis=-1)
        pick = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - pick)

    probs = jax.nn.softmax(x @ W, axis=-1)
    # exact GN/Fisher diagonal of the MEAN loss: (1/B^2) sum_b x^2 (p-p^2)
    # times B (the estimator's B factor) -> (1/B) sum_b x^2 p(1-p)
    exact = jnp.einsum("bd,bk->dk", x ** 2, probs * (1 - probs)) / B

    keys = jax.random.split(jax.random.PRNGKey(2), 4000)

    def one(rk):
        y = jax.random.categorical(rk, jnp.log(probs), axis=-1)
        g = jax.grad(loss)(W, y)
        return B * g * g

    est = jnp.mean(jax.vmap(one)(keys), axis=0)
    # E[B*ghat^2] = (1/B) diag-Fisher + (1-1/B)*meanGrad^2-ish; dominant
    # term must match within MC error
    np.testing.assert_allclose(est, exact, rtol=0.35, atol=5e-3)
