"""repro.comm tests: compressor round-trip invariants, error-feedback
accumulation, partial-participation weighting, Pallas-vs-reference
kernel equivalence, engine bit-exactness and byte accounting — for all
three wire streams (uplink / downlink / hessian)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import accounting, downlink as cdown, flat as cflat
from repro.comm.compressors import (make_compressor,
                                    make_stream_compressor,
                                    participation_mask)
from repro.configs.base import CommConfig, FedConfig
from repro.core.fed import FedEngine
from repro.data import synthetic as syn
from repro.models.small import MLPTask
from repro.utils.tree import tree_sub


def _cfg(**kw) -> CommConfig:
    return CommConfig(**kw)


def _spec_and_buf(key, total=3000, cols=128):
    tree = {"a": jax.random.normal(key, (50, 30)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (1500,))}
    spec = cflat.flat_spec(tree, cols=cols)
    assert spec.total == total
    return tree, spec, cflat.pack(tree, spec)


# ------------------------------------------------------------ flat layout
def test_pack_unpack_roundtrip_exact():
    tree, spec, flat = _spec_and_buf(jax.random.PRNGKey(0))
    out = cflat.unpack(flat, spec)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # pad tail is zero
    assert float(jnp.sum(jnp.abs(flat.reshape(-1)[spec.total:]))) == 0.0


# ------------------------------------------------------------ compressors
def test_identity_roundtrip_exact():
    _, spec, flat = _spec_and_buf(jax.random.PRNGKey(1))
    comp = make_compressor(_cfg(), spec)
    xhat, _ = comp.roundtrip(jax.random.PRNGKey(2), flat)
    np.testing.assert_array_equal(np.asarray(xhat), np.asarray(flat))


def test_int8_unbiased_over_seeds():
    """E[decode(encode(x))] == x for stochastic rounding (Eq. of QSGD)."""
    _, spec, flat = _spec_and_buf(jax.random.PRNGKey(3))
    comp = make_compressor(_cfg(compressor="int8"), spec)
    n_seeds = 200
    acc = jnp.zeros_like(flat)
    for s in range(n_seeds):
        xhat, _ = comp.roundtrip(jax.random.PRNGKey(1000 + s), flat)
        acc = acc + xhat
    mean = np.asarray(acc / n_seeds)
    # per-row quantization step = max|row|/127; mean error shrinks ~1/sqrt(N)
    step = np.asarray(jnp.max(jnp.abs(flat), axis=1, keepdims=True)) / 127.0
    err = np.abs(mean - np.asarray(flat))
    assert np.all(err <= 5.0 * step / np.sqrt(n_seeds) + 1e-7)


@pytest.mark.parametrize("bits,name", [(8, "int8"), (4, "int4")])
def test_quant_error_bounded_by_step(bits, name):
    _, spec, flat = _spec_and_buf(jax.random.PRNGKey(4))
    comp = make_compressor(_cfg(compressor=name), spec)
    payload = comp.encode(jax.random.PRNGKey(5), flat)
    assert payload["q"].dtype == jnp.int8
    qmax = 2 ** (bits - 1) - 1
    assert int(jnp.max(jnp.abs(payload["q"]))) <= qmax
    xhat = comp.decode(payload)
    step = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / qmax
    assert np.all(np.abs(np.asarray(xhat - flat))
                  <= np.asarray(step) * (1 + 1e-5) + 1e-7)


def test_topk_support_size_and_values():
    _, spec, flat = _spec_and_buf(jax.random.PRNGKey(6))
    comm = _cfg(compressor="topk", topk_ratio=0.01)
    comp = make_compressor(comm, spec)
    k = accounting.topk_k(comm, spec.total)
    payload = comp.encode(None, flat)
    assert payload["idx"].shape == (k,) and payload["val"].shape == (k,)
    xhat = comp.decode(payload)
    nnz = int(jnp.sum(xhat != 0))
    assert nnz == k     # random floats: no ties, no zero survivors
    # the surviving coordinates are exactly the k largest magnitudes
    v = np.abs(np.asarray(flat).reshape(-1))
    thr = np.sort(v)[-k]
    kept = np.abs(np.asarray(xhat).reshape(-1)[: spec.total])
    assert np.all(kept[kept > 0] >= thr - 1e-7)


def test_signsgd_decode_is_scaled_sign():
    _, spec, flat = _spec_and_buf(jax.random.PRNGKey(7))
    comp = make_compressor(_cfg(compressor="signsgd"), spec)
    payload = comp.encode(None, flat)
    scale = float(jnp.sum(jnp.abs(flat)) / spec.total)
    assert np.isclose(float(payload["scale"]), scale, rtol=1e-6)
    xhat = comp.decode(payload)
    np.testing.assert_allclose(np.asarray(xhat),
                               scale * np.sign(np.asarray(flat)),
                               rtol=1e-6, atol=1e-7)


def test_signsgd_majority_vote_combine():
    _, spec, flat = _spec_and_buf(jax.random.PRNGKey(8))
    comp = make_compressor(
        _cfg(compressor="signsgd", sign_majority=True), spec)
    agg = jnp.asarray([[0.3, -0.1, 0.0, 2.0]], jnp.float32)
    out = comp.server_combine(agg, jnp.asarray(0.5))
    np.testing.assert_allclose(np.asarray(out),
                               [[0.5, -0.5, 0.0, 0.5]], rtol=1e-6)


def test_error_feedback_identity_accumulation():
    """wire + residual == input: what the EF update stores is exactly the
    part of the (EF-corrected) delta that did not make it onto the wire."""
    _, spec, flat = _spec_and_buf(jax.random.PRNGKey(9))
    comp = make_compressor(_cfg(compressor="topk", topk_ratio=0.01), spec)
    ef = jnp.zeros_like(flat)
    for r in range(3):
        corrected = flat + ef
        xhat, _ = comp.roundtrip(jax.random.PRNGKey(50 + r), corrected)
        ef = corrected - xhat
        np.testing.assert_allclose(np.asarray(xhat + ef),
                                   np.asarray(corrected),
                                   rtol=1e-6, atol=1e-7)
    # EF keeps total mass: residual norm is bounded by the input norm
    assert float(jnp.linalg.norm(ef)) < float(jnp.linalg.norm(flat)) * 3


# ----------------------------------------------- Pallas kernel equivalence
@pytest.mark.parametrize("name", ["int8", "int4", "topk", "signsgd"])
def test_pallas_roundtrip_matches_reference(name):
    _, spec, flat = _spec_and_buf(jax.random.PRNGKey(10))
    kw = {"topk_ratio": 0.02} if name == "topk" else {}
    ref = make_compressor(_cfg(compressor=name, **kw), spec)
    pal = make_compressor(
        _cfg(compressor=name, use_pallas=True, **kw), spec)
    key = jax.random.PRNGKey(11)
    a, _ = ref.roundtrip(key, flat)
    b, _ = pal.roundtrip(key, flat)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-7)


# ------------------------------------------------- partial participation
def test_participation_mask_exact_count_and_seeded():
    key = jax.random.PRNGKey(12)
    m1 = participation_mask(key, 16, 5)
    m2 = participation_mask(key, 16, 5)
    assert int(jnp.sum(m1)) == 5
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    m3 = participation_mask(jax.random.PRNGKey(13), 16, 5)
    assert not np.array_equal(np.asarray(m1), np.asarray(m3))


# --------------------------------------------------------- byte accounting
HDR = cflat.HEADER_BYTES


def test_wire_bytes_formulas():
    n = 100_000
    cc = _cfg()
    # every payload carries the versioned 24-byte header
    assert accounting.wire_bytes(cc, n) == HDR + 4 * n
    groups = -(-n // cc.quant_block)
    assert accounting.wire_bytes(_cfg(compressor="int8"), n) == \
        HDR + (8 * n + 32 * groups + 7) // 8
    assert accounting.wire_bytes(_cfg(compressor="int4"), n) == \
        HDR + (4 * n + 32 * groups + 7) // 8
    k = accounting.topk_k(_cfg(compressor="topk"), n)
    assert accounting.wire_bytes(_cfg(compressor="topk"), n) == \
        HDR + 8 * k
    assert accounting.wire_bytes(_cfg(compressor="signsgd"), n) == \
        HDR + (n + 32 + 7) // 8
    # int8 uplink reduction vs fp32 identity (acceptance: >= 3.5x)
    ratio = accounting.wire_bytes(cc, n) / accounting.wire_bytes(
        _cfg(compressor="int8"), n)
    assert ratio >= 3.5
    rb = accounting.round_bytes(_cfg(participation=0.5), n, 8)
    assert rb["participants"] == 4
    assert rb["uplink_bytes"] == 4 * (HDR + 4 * n)
    assert rb["downlink_bytes"] == 4 * (HDR + 4 * n)


def test_per_stream_quant_block_prices_groups():
    """The hessian/downlink streams may pack with their own (coarser)
    quant_block: fewer scale groups on the wire, priced exactly."""
    n = 100_000
    comm = _cfg(compressor="int8", downlink_compressor="int8",
                hessian_compressor="int8",
                downlink_quant_block=2048, hessian_quant_block=4096)
    assert comm.stream("uplink").quant_block == 1024
    assert comm.stream("downlink").quant_block == 2048
    assert comm.stream("hessian").quant_block == 4096

    def int8_bytes(qb):
        return HDR + (8 * n + 32 * (-(-n // qb)) + 7) // 8

    assert accounting.stream_bytes(comm, "uplink", n) == int8_bytes(1024)
    assert accounting.stream_bytes(comm, "downlink", n) == int8_bytes(2048)
    assert accounting.stream_bytes(comm, "hessian", n) == int8_bytes(4096)
    # per-stream topk_ratio override reaches topk_k the same way
    comm_tk = _cfg(compressor="topk", topk_ratio=0.01,
                   downlink_compressor="topk", downlink_topk_ratio=0.05)
    assert accounting.topk_k(comm_tk.stream("downlink"), n) == \
        accounting.topk_k(_cfg(compressor="topk", topk_ratio=0.05), n)
    assert accounting.topk_k(comm_tk.stream("uplink"), n) == \
        accounting.topk_k(comm_tk, n)


# ------------------------------------------------------- engine integration
@pytest.fixture(scope="module")
def fed_setup():
    key = jax.random.PRNGKey(0)
    x, y = syn.make_image_data(key, 1024, "mnist", noise=1.0)
    part = syn.dirichlet_partition(jax.random.PRNGKey(1), y, 4, alpha=0.5)
    tr, _ = syn.train_test_split(part)
    task = MLPTask(hidden=32)
    batches = syn.client_batches(key, x, y, tr, 32)
    return task, batches


def _run(task, fed, batches, rounds=2):
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(2))
    rf = jax.jit(eng.round)
    for r in range(rounds):
        state, metrics = rf(state, batches, jax.random.PRNGKey(100 + r))
    return state, metrics


@pytest.mark.parametrize("strategy", ["parallel", "sequential"])
@pytest.mark.parametrize("optimizer", ["fed_sophia", "fedavg"])
def test_identity_full_participation_bit_exact(fed_setup, strategy,
                                               optimizer):
    """Acceptance: identity at full participation == pre-comm round,
    bitwise, for fed_sophia and fedavg under both strategies."""
    task, batches = fed_setup
    base = FedConfig(num_clients=4, local_iters=2, optimizer=optimizer,
                     strategy=strategy, lr=0.01, tau=2)
    with_comm = dataclasses.replace(
        base, comm=CommConfig(compressor="identity", participation=1.0))
    s0, m0 = _run(task, base, batches)
    s1, _ = _run(task, with_comm, batches)
    for a, b in zip(jax.tree.leaves(s0["params"]),
                    jax.tree.leaves(s1["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # identity uplink: C clients x (header + 4 bytes x n params)
    n = sum(p.size for p in jax.tree.leaves(s0["params"]))
    assert float(m0["uplink_bytes"]) == 4 * (cflat.HEADER_BYTES + 4 * n)


def test_strategies_agree_under_compression(fed_setup):
    """parallel and sequential produce the same compressed round."""
    task, batches = fed_setup
    outs = {}
    for strat in ("parallel", "sequential"):
        fed = FedConfig(num_clients=4, local_iters=2,
                        optimizer="fed_sophia", strategy=strat, lr=0.01,
                        tau=2, comm=CommConfig(compressor="int8",
                                               participation=0.5))
        outs[strat], _ = _run(task, fed, batches)
    for a, b in zip(jax.tree.leaves(outs["parallel"]["params"]),
                    jax.tree.leaves(outs["sequential"]["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_partial_participation_weighting(fed_setup):
    """With identity compression and S<C the server update equals the
    plain mean over exactly the sampled clients' deltas."""
    task, batches = fed_setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fedavg",
                    lr=0.05, comm=CommConfig(participation=0.5))
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(2))
    params = state["params"]
    rng = jax.random.PRNGKey(100)
    new, metrics = jax.jit(eng.round)(state, batches, rng)
    assert float(metrics["participants"]) == 2.0
    mask = np.asarray(participation_mask(
        jax.random.fold_in(rng, 0x9A70 + fed.comm.seed), 4, 2))
    # manual: mean of participating clients' local-trained params deltas
    deltas = []
    for i in np.nonzero(mask)[0]:
        b = jax.tree.map(lambda a, i=i: a[i], batches)
        crng = jax.random.fold_in(rng, int(i))
        p_i, _ = eng._local_sgd(params, b, crng, jnp.asarray(0.05))
        deltas.append(tree_sub(p_i, params))
    manual = jax.tree.map(
        lambda p, *ds: p + sum(np.asarray(d) for d in ds) / len(deltas),
        params, *deltas)
    for a, b in zip(jax.tree.leaves(new["params"]),
                    jax.tree.leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_error_feedback_auto_gating(fed_setup):
    """'auto' materialises EF only for biased compressors; True forces
    it for any lossy one; identity never allocates."""
    task, _ = fed_setup
    def ef_alloc(**kw):
        fed = FedConfig(num_clients=4, local_iters=1,
                        comm=CommConfig(**kw))
        return "comm_ef" in FedEngine(task, fed).init(jax.random.PRNGKey(0))
    assert not ef_alloc(compressor="identity")
    assert not ef_alloc(compressor="int8")
    assert ef_alloc(compressor="topk")
    assert ef_alloc(compressor="signsgd")
    assert ef_alloc(compressor="int8", error_feedback=True)
    assert not ef_alloc(compressor="topk", error_feedback=False)


def test_error_feedback_state_in_engine(fed_setup):
    """Lossy compressor allocates per-client EF; participants' residuals
    move, non-participants' stay frozen; training stays finite."""
    task, batches = fed_setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fed_sophia",
                    lr=0.01, tau=2,
                    comm=CommConfig(compressor="topk", topk_ratio=0.05,
                                    participation=0.5))
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(2))
    assert "comm_ef" in state and state["comm_ef"].shape[0] == 4
    rng = jax.random.PRNGKey(100)
    new, metrics = jax.jit(eng.round)(state, batches, rng)
    mask = np.asarray(participation_mask(
        jax.random.fold_in(rng, 0x9A70 + fed.comm.seed), 4, 2))
    ef = np.asarray(new["comm_ef"])
    for i in range(4):
        moved = np.abs(ef[i]).sum() > 0
        assert moved == bool(mask[i] > 0), (i, mask)
    assert np.isfinite(float(metrics["loss"]))
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree.leaves(new["params"]))


@pytest.mark.parametrize("name", ["int8", "int4", "topk", "signsgd"])
def test_all_compressors_train_finite(fed_setup, name):
    task, batches = fed_setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fed_sophia",
                    lr=0.01, tau=2, comm=CommConfig(compressor=name))
    state, metrics = _run(task, fed, batches, rounds=3)
    assert np.isfinite(float(metrics["loss"])), name
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree.leaves(state["params"])), name


# ------------------------------------------------------- downlink stream
def test_stream_views_resolve_per_stream_compressors():
    comm = CommConfig(compressor="topk", downlink_compressor="int8",
                      hessian_compressor="int4")
    assert comm.stream("uplink").compressor == "topk"
    assert comm.stream("downlink").compressor == "int8"
    assert comm.stream("hessian").compressor == "int4"
    assert comm.multi_stream and comm.downlink_enabled
    assert not CommConfig().multi_stream
    with pytest.raises(ValueError):
        comm.stream("sideband")


def test_uplink_only_round_matches_manual_pr1_pipeline(fed_setup):
    """With downlink='identity' and hessian off, the round is exactly
    the PR-1 uplink pipeline — pinned against a manual recomputation
    (local train -> pack delta -> roundtrip -> mean -> apply), so a
    regression that lets the extra streams leak ops into the disabled
    path fails loudly."""
    task, batches = fed_setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fedavg",
                    lr=0.05, comm=CommConfig(compressor="int8"))
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(2))
    assert cdown.MODEL_KEY not in state and cdown.EF_KEY not in state
    params = state["params"]
    rng = jax.random.PRNGKey(100)
    new, _ = jax.jit(eng.round)(state, batches, rng)
    spec = cflat.flat_spec(params, cols=fed.comm.quant_block)
    comp = make_compressor(fed.comm, spec)
    wires = []
    for i in range(4):
        b = jax.tree.map(lambda a, i=i: a[i], batches)
        crng = jax.random.fold_in(rng, i)
        p_i, _ = eng._local_sgd(params, b, crng, jnp.asarray(0.05))
        delta = cflat.pack(tree_sub(p_i, params), spec)
        xhat, _ = comp.roundtrip(jax.random.fold_in(crng, 0xC0), delta)
        wires.append(xhat)
    agg = cflat.unpack(jnp.sum(jnp.stack(wires), axis=0) / 4, spec)
    manual = jax.tree.map(lambda p, d: (p + d).astype(p.dtype),
                          params, agg)
    for a, b in zip(jax.tree.leaves(new["params"]),
                    jax.tree.leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_downlink_ef_auto_gating(fed_setup):
    """Downlink replicas allocate whenever the stream is on; server-side
    EF only for biased downlink compressors (or when forced)."""
    task, _ = fed_setup
    def keys(**kw):
        fed = FedConfig(num_clients=4, comm=CommConfig(**kw))
        st = FedEngine(task, fed).init(jax.random.PRNGKey(0))
        return cdown.MODEL_KEY in st, cdown.EF_KEY in st
    assert keys() == (False, False)
    assert keys(downlink_compressor="int8") == (True, False)
    assert keys(downlink_compressor="topk") == (True, True)
    assert keys(downlink_compressor="signsgd") == (True, True)
    assert keys(downlink_compressor="int8",
                downlink_error_feedback=True) == (True, True)


def test_downlink_replicas_track_server_model(fed_setup):
    """Participants' replicas equal their broadcast reconstruction
    (within one quant step of the pre-update server model); frozen for
    non-participants."""
    task, batches = fed_setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fedavg",
                    lr=0.05,
                    comm=CommConfig(downlink_compressor="int8",
                                    participation=0.5))
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(2))
    packed0 = np.asarray(cflat.pack(
        state["params"],
        cflat.flat_spec(state["params"], cols=fed.comm.quant_block)))
    rng = jax.random.PRNGKey(100)
    new, _ = jax.jit(eng.round)(state, batches, rng)
    mask = np.asarray(participation_mask(
        jax.random.fold_in(rng, 0x9A70 + fed.comm.seed), 4, 2))
    rep = np.asarray(new[cdown.MODEL_KEY])
    for i in range(4):
        if mask[i]:
            # round-1 broadcast delta is 0 (replicas start in sync), so
            # the replica stays at the initial model up to quantization
            step = np.abs(packed0).max(axis=1, keepdims=True) / 127 + 1e-7
            assert np.all(np.abs(rep[i] - packed0) <= step * (1 + 1e-5))
        else:
            np.testing.assert_array_equal(rep[i], packed0)


def test_bidirectional_strategies_agree(fed_setup):
    """parallel and sequential produce the same round under full
    three-stream compression with partial participation."""
    task, batches = fed_setup
    outs = {}
    for strat in ("parallel", "sequential"):
        fed = FedConfig(num_clients=4, local_iters=2,
                        optimizer="fed_sophia", strategy=strat, lr=0.01,
                        tau=2,
                        comm=CommConfig(compressor="int8",
                                        downlink_compressor="int8",
                                        hessian_compressor="int4",
                                        participation=0.5))
        outs[strat], _ = _run(task, fed, batches)
    for a, b in zip(jax.tree.leaves(outs["parallel"]),
                    jax.tree.leaves(outs["sequential"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dn", ["int8", "int4", "topk", "signsgd"])
def test_bidirectional_trains_finite(fed_setup, dn):
    task, batches = fed_setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fed_sophia",
                    lr=0.01, tau=2,
                    comm=CommConfig(compressor="int8",
                                    downlink_compressor=dn,
                                    topk_ratio=0.05,
                                    hessian_compressor="int4"))
    state, metrics = _run(task, fed, batches, rounds=3)
    assert np.isfinite(float(metrics["loss"])), dn
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree.leaves(state["params"])), dn


def test_downlink_broadcast_pallas_matches_reference():
    """Fused delta+quant+apply+residual kernel == pure-JAX broadcast."""
    _, spec, theta = _spec_and_buf(jax.random.PRNGKey(20))
    key = jax.random.PRNGKey(21)
    ref_model = theta + 0.1 * jax.random.normal(
        jax.random.fold_in(key, 0), theta.shape)
    ef = 0.01 * jax.random.normal(jax.random.fold_in(key, 1), theta.shape)
    for name in ("int8", "int4"):
        for ef_row in (None, ef):
            a = cdown.broadcast(
                make_stream_compressor(
                    CommConfig(downlink_compressor=name,
                               downlink_error_feedback=ef_row is not None),
                    "downlink", spec),
                key, theta, ref_model, ef_row)
            b = cdown.broadcast(
                make_stream_compressor(
                    CommConfig(downlink_compressor=name, use_pallas=True,
                               downlink_error_feedback=ef_row is not None),
                    "downlink", spec),
                key, theta, ref_model, ef_row)
            np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                                       rtol=1e-6, atol=1e-7)
            if ef_row is not None:
                np.testing.assert_allclose(np.asarray(a[1]),
                                           np.asarray(b[1]),
                                           rtol=1e-6, atol=1e-7)


# -------------------------------------------------------- hessian stream
def test_hessian_stream_requires_persistent_sophia(fed_setup):
    task, _ = fed_setup
    comm = CommConfig(hessian_compressor="int4")
    with pytest.raises(ValueError):
        FedEngine(task, FedConfig(optimizer="fedavg", comm=comm))
    with pytest.raises(ValueError):
        FedEngine(task, FedConfig(optimizer="fed_sophia",
                                  persistent_client_state=False, comm=comm))
    FedEngine(task, FedConfig(optimizer="fed_sophia", comm=comm))  # ok


def test_hessian_curvature_averaging(fed_setup):
    """Participants leave the round with identical (averaged) h-EMAs;
    non-participants keep theirs."""
    task, batches = fed_setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fed_sophia",
                    lr=0.01, tau=1,
                    comm=CommConfig(hessian_compressor="identity",
                                    participation=0.5))
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(2))
    rng = jax.random.PRNGKey(100)
    new, _ = jax.jit(eng.round)(state, batches, rng)
    mask = np.asarray(participation_mask(
        jax.random.fold_in(rng, 0x9A70 + fed.comm.seed), 4, 2))
    part = [int(i) for i in np.nonzero(mask)[0]]
    out_ = [int(i) for i in np.nonzero(mask == 0)[0]]
    for h in jax.tree.leaves(new["client_opt"].h):
        h = np.asarray(h)
        np.testing.assert_allclose(h[part[0]], h[part[1]],
                                   rtol=1e-6, atol=1e-7)
    for h0, h1 in zip(jax.tree.leaves(state["client_opt"].h),
                      jax.tree.leaves(new["client_opt"].h)):
        for i in out_:
            np.testing.assert_array_equal(np.asarray(h0)[i],
                                          np.asarray(h1)[i])


# ------------------------------------------- multi-stream byte accounting
def test_round_bytes_multi_stream():
    n, C = 100_000, 8
    comm = CommConfig(compressor="int8", downlink_compressor="int8",
                      hessian_compressor="int4", participation=0.5)
    rb = accounting.round_bytes(comm, n, C)
    s = rb["participants"]
    assert s == 4
    int8_b = accounting.wire_bytes(CommConfig(compressor="int8"), n)
    int4_b = accounting.wire_bytes(CommConfig(compressor="int4"), n)
    assert rb["uplink_bytes"] == s * int8_b
    assert rb["downlink_bytes"] == s * int8_b
    assert rb["hessian_uplink_bytes"] == s * int4_b
    # the averaged-curvature broadcast is ONE common payload
    assert rb["hessian_downlink_bytes"] == int4_b
    assert rb["total_bytes"] == sum(
        rb[k] for k in ("uplink_bytes", "downlink_bytes",
                        "hessian_uplink_bytes", "hessian_downlink_bytes"))
    # hessian off -> zero curvature bytes, identical legacy totals
    legacy = accounting.round_bytes(CommConfig(participation=0.5), n, C)
    assert legacy["hessian_uplink_bytes"] == 0
    assert legacy["hessian_downlink_bytes"] == 0
    assert legacy["uplink_bytes"] == legacy["downlink_bytes"] \
        == 4 * (HDR + 4 * n)


def test_bidirectional_total_reduction_at_least_3x():
    """Acceptance: the bidirectional int4/int8/int4 regime moves >= 3x
    fewer total bytes than the uncompressed baseline at matched
    rounds (pure accounting — the benchmark reports the same numbers)."""
    n, C = 19_000, 6     # ~the benchmark CNN scale
    base = accounting.round_bytes(CommConfig(), n, C)["total_bytes"]
    bidir = accounting.round_bytes(
        CommConfig(compressor="int4", downlink_compressor="int8",
                   hessian_compressor="int4"), n, C)["total_bytes"]
    assert base / bidir >= 3.0


# ------------------------------------------- per-stream packing geometry
def test_engine_per_stream_geometry_trains(fed_setup):
    """Downlink/hessian streams packing with their own quant_block
    (different rows x cols than the uplink) still train finite, with
    replicas allocated in the downlink's own layout."""
    task, batches = fed_setup
    comm = CommConfig(compressor="int8", downlink_compressor="int8",
                      hessian_compressor="int4", quant_block=256,
                      downlink_quant_block=512, hessian_quant_block=1024)
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fed_sophia",
                    lr=0.01, tau=2, comm=comm)
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(2))
    params = state["params"]
    spec_dn = cflat.flat_spec(params, cols=512)
    assert state[cdown.MODEL_KEY].shape[1:] == (spec_dn.rows, 512)
    rt = eng.comm_runtime(params)
    assert (rt.spec.cols, rt.spec_dn.cols, rt.spec_h.cols) == \
        (256, 512, 1024)
    new, metrics = jax.jit(eng.round)(state, batches,
                                      jax.random.PRNGKey(100))
    assert np.isfinite(float(metrics["loss"]))
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree.leaves(new["params"]))


def test_repack_relays_geometry():
    tree, _, _ = _spec_and_buf(jax.random.PRNGKey(30))
    a = cflat.flat_spec(tree, cols=128)
    b = cflat.flat_spec(tree, cols=512)
    buf = cflat.pack(tree, a)
    out = cflat.repack(buf, a, b)
    assert out.shape == (b.rows, b.cols)
    np.testing.assert_array_equal(np.asarray(cflat.pack(tree, b)),
                                  np.asarray(out))
    with pytest.raises(ValueError):
        cflat.repack(buf, a, cflat.flat_spec({"x": jnp.zeros(7)}, cols=4))


# ------------------------------------------- wire headers (FSWB v2 spec;
# v1-compat matrix lives in tests/test_residency.py)
def test_header_pack_unpack_roundtrip():
    h = cflat.Header(compressor="int4", total=3000, quant_block=128,
                     aux=0)
    raw = h.pack()
    assert len(raw) == cflat.HEADER_BYTES
    assert raw[:4] == cflat.WIRE_MAGIC
    assert cflat.Header.unpack(raw) == h
    assert cflat.Header.from_dict(h.to_dict()) == h


def test_header_rejects_bad_magic_and_version():
    h = cflat.Header(compressor="int8", total=10, quant_block=4)
    raw = h.pack()
    with pytest.raises(ValueError, match="magic"):
        cflat.Header.unpack(b"XXXX" + raw[4:])
    future = dataclasses.replace(h, version=cflat.WIRE_VERSION + 1)
    with pytest.raises(ValueError, match="version"):
        cflat.Header.unpack(future.pack())
    with pytest.raises(ValueError, match="too short"):
        cflat.Header.unpack(raw[:10])


@pytest.mark.parametrize("name", ["identity", "int8", "int4", "topk",
                                  "signsgd"])
def test_serialize_starts_with_header(name):
    _, spec, flat = _spec_and_buf(jax.random.PRNGKey(31))
    comp = make_compressor(_cfg(compressor=name, topk_ratio=0.02,
                                quant_block=128), spec)
    raw = comp.serialize(comp.encode(jax.random.PRNGKey(32), flat))
    h = cflat.Header.unpack(raw)
    assert h.compressor == name
    assert h.total == spec.total and h.quant_block == spec.cols
    if name == "topk":
        assert h.aux == comp.k
    assert len(raw) == accounting.wire_bytes(
        _cfg(compressor=name, topk_ratio=0.02, quant_block=128),
        spec.total)


def test_check_headers_rejects_mismatch(fed_setup):
    """Restoring comm/EF state under a changed comm config fails with a
    clear error naming the stream and field."""
    task, _ = fed_setup
    def headers(**kw):
        fed = FedConfig(num_clients=4, comm=CommConfig(**kw))
        eng = FedEngine(task, fed)
        state = eng.init(jax.random.PRNGKey(0))
        return eng.wire_headers(state["params"])
    saved = headers(compressor="int8", downlink_compressor="int8")
    cflat.check_headers(saved, saved)         # identical: fine
    with pytest.raises(ValueError, match="uplink.*quant_block"):
        cflat.check_headers(saved, headers(compressor="int8",
                                           downlink_compressor="int8",
                                           quant_block=512))
    with pytest.raises(ValueError, match="compressor"):
        cflat.check_headers(saved, headers(compressor="int4",
                                           downlink_compressor="int8"))
    with pytest.raises(ValueError, match="downlink"):
        cflat.check_headers(saved, headers(compressor="int8"))
    with pytest.raises(ValueError, match="hessian"):
        cflat.check_headers(saved, headers(compressor="int8",
                                           downlink_compressor="int8",
                                           hessian_compressor="int4"))


def test_check_headers_rejects_headerless_manifest():
    with pytest.raises(ValueError, match="predates"):
        cflat.check_headers({}, {"uplink": {"version": 1}})


def test_restore_params_rebuilds_wire_state(fed_setup):
    """Restoring params must re-sync the downlink replicas to the
    restored model and zero the EF residuals — stale wire-layout rows
    referencing the discarded init would corrupt the delta coding."""
    task, batches = fed_setup
    fed = FedConfig(num_clients=4, local_iters=2, optimizer="fedavg",
                    lr=0.05,
                    comm=CommConfig(compressor="topk", topk_ratio=0.05,
                                    downlink_compressor="int8"))
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(2))
    # train a round so EF residuals and replicas move off their init
    state, _ = jax.jit(eng.round)(state, batches, jax.random.PRNGKey(9))
    restored_params = jax.tree.map(lambda x: x + 1.0, state["params"])
    new = eng.restore_params(state, restored_params)
    spec_dn = cflat.flat_spec(restored_params,
                              cols=fed.comm.quant_block)
    packed = np.asarray(cflat.pack(restored_params, spec_dn))
    for i in range(4):
        np.testing.assert_array_equal(
            np.asarray(new[cdown.MODEL_KEY][i]), packed)
    assert float(np.abs(np.asarray(new["comm_ef"])).sum()) == 0.0
    for a, b in zip(jax.tree.leaves(new["params"]),
                    jax.tree.leaves(restored_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_wire_headers_survive_ckpt_manifest(fed_setup, tmp_path):
    """End to end: headers stored in the checkpoint manifest round-trip
    through JSON and validate (or reject) on restore."""
    from repro.checkpoint import ckpt
    task, _ = fed_setup
    fed = FedConfig(num_clients=4, comm=CommConfig(compressor="topk",
                                                   topk_ratio=0.05))
    eng = FedEngine(task, fed)
    state = eng.init(jax.random.PRNGKey(0))
    wire = eng.wire_headers(state["params"])
    ckpt.save(str(tmp_path), state["params"], step=3,
              extra={"wire": wire})
    saved = ckpt.load_manifest(str(tmp_path))["extra"]["wire"]
    cflat.check_headers(saved, wire)
    fed2 = FedConfig(num_clients=4, comm=CommConfig(compressor="topk",
                                                    topk_ratio=0.10))
    eng2 = FedEngine(task, fed2)
    with pytest.raises(ValueError, match="aux"):
        cflat.check_headers(saved, eng2.wire_headers(state["params"]))
