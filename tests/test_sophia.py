"""Unit tests for the Sophia update (Alg. 1 lines 7-16)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sophia


def _tree():
    return {"a": jnp.array([1.0, -2.0, 3.0]),
            "b": {"c": jnp.ones((2, 2))}}


def test_init_state_zeros():
    st = sophia.init_state(_tree())
    for leaf in jax.tree.leaves(st.m) + jax.tree.leaves(st.h):
        assert jnp.all(leaf == 0)


def test_update_m_ema():
    m = {"a": jnp.array([1.0])}
    g = {"a": jnp.array([3.0])}
    out = sophia.update_m(m, g, beta1=0.9)
    np.testing.assert_allclose(out["a"], 0.9 * 1.0 + 0.1 * 3.0)


def test_update_h_ema():
    h = {"a": jnp.array([2.0])}
    e = {"a": jnp.array([4.0])}
    out = sophia.update_h(h, e, beta2=0.95)
    np.testing.assert_allclose(out["a"], 0.95 * 2.0 + 0.05 * 4.0, rtol=1e-6)


def test_clip_bounds():
    z = jnp.array([-5.0, -0.01, 0.0, 0.02, 7.0])
    out = sophia.clip(z, 0.04)
    assert jnp.all(out <= 0.04) and jnp.all(out >= -0.04)
    np.testing.assert_allclose(out, [-0.04, -0.01, 0.0, 0.02, 0.04])


def test_apply_update_matches_manual():
    lr, rho, eps, wd = 0.01, 0.05, 1e-12, 0.1
    theta = jnp.array([1.0, -1.0])
    m = jnp.array([0.5, -2.0])
    h = jnp.array([10.0, 0.0])      # second entry exercises eps guard
    out = sophia.apply_update({"t": theta}, {"t": m}, {"t": h},
                              lr=lr, rho=rho, eps=eps, weight_decay=wd)["t"]
    t1 = theta - lr * wd * theta
    step = jnp.clip(m / jnp.maximum(h, eps), -rho, rho)
    np.testing.assert_allclose(out, t1 - lr * step, rtol=1e-6)


def test_step_size_bounded_by_lr_rho():
    """|theta_new - theta_wd| <= lr*rho elementwise — the paper's guard."""
    key = jax.random.PRNGKey(0)
    theta = {"w": jax.random.normal(key, (64,))}
    grads = {"w": 100.0 * jax.random.normal(jax.random.fold_in(key, 1), (64,))}
    st = sophia.init_state(theta)
    h_hat = {"w": jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (64,)))}
    lr, rho = 0.01, 0.04
    new, _ = sophia.sophia_step(theta, grads, st, h_hat, jnp.asarray(True),
                                lr=lr, beta1=0.9, beta2=0.95, rho=rho,
                                eps=1e-12, weight_decay=0.0)
    delta = jnp.abs(new["w"] - theta["w"])
    assert jnp.all(delta <= lr * rho + 1e-7)


def test_h_update_gating():
    theta = {"w": jnp.ones((4,))}
    grads = {"w": jnp.ones((4,))}
    st = sophia.init_state(theta)
    h_hat = {"w": 2.0 * jnp.ones((4,))}
    _, st_on = sophia.sophia_step(theta, grads, st, h_hat, jnp.asarray(True),
                                  lr=0.1, beta1=0.9, beta2=0.5, rho=1.0,
                                  eps=1e-12, weight_decay=0.0)
    _, st_off = sophia.sophia_step(theta, grads, st, h_hat, jnp.asarray(False),
                                   lr=0.1, beta1=0.9, beta2=0.5, rho=1.0,
                                   eps=1e-12, weight_decay=0.0)
    np.testing.assert_allclose(st_on.h["w"], 1.0)   # 0.5*0 + 0.5*2
    np.testing.assert_allclose(st_off.h["w"], 0.0)  # unchanged
