"""Model-zoo correctness: decode==prefill consistency, chunked attention
vs dense, recurrent blocks vs step-by-step oracles, MoE dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig
from repro.models import layers as L
from repro.models import recurrent as R
from repro.models import transformer as T

BASE = dict(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
            d_ff=128, vocab_size=128)


def _cfg(**kw):
    d = {**BASE, **kw}
    fam = d.pop("family", "dense")
    name = d.pop("name", "t")
    return ModelConfig(name=name, family=fam, **d)


# --------------------------------------------------------------- attention
def test_chunked_attention_matches_dense():
    key = jax.random.PRNGKey(0)
    B, S, H, K, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, hd))
    pos = jnp.arange(S)
    for window, softc in [(None, None), (16, None), (None, 30.0)]:
        bias = L.attn_mask_bias(pos, pos, causal=True, window=window)
        dense = L.attention_dense(q, k, v, bias, 0.25, softc)
        chunk = L.attention_chunked(q, k, v, q_pos=pos, k_pos=pos,
                                    causal=True, window=window, scale=0.25,
                                    softcap_val=softc, kv_chunk=16)
        np.testing.assert_allclose(dense, chunk, rtol=2e-4, atol=2e-5)


# ------------------------------------------------- decode == full forward
def _decode_consistency(cfg, S=32, B=2, atol=2e-3):
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    if cfg.embedding_inputs:
        feats = jax.random.normal(key, (B, S + 1, cfg.d_model))
        full_batch = {"embeds": feats}
        tok = lambda i: {"embeds": feats[:, i:i + 1]}
    else:
        tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
        full_batch = {"tokens": tokens}
        tok = lambda i: {"tokens": tokens[:, i:i + 1]}

    logits_full, cache_pre, _ = T.forward(
        params, cfg, jax.tree.map(lambda x: x[:, :S], full_batch),
        want_cache=True, remat=False)
    cache = T.prefill_to_decode_cache(cfg, cache_pre, S, S + 4)
    logits_dec, _ = T.decode_step(params, cfg, tok(S), cache,
                                  jnp.asarray(S, jnp.int32))
    logits_ref, _, _ = T.forward(params, cfg, full_batch, remat=False)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_ref[:, S]),
                               rtol=1e-3, atol=atol)


def test_decode_gqa():
    _decode_consistency(_cfg(qk_norm=True))


def test_decode_local_global():
    _decode_consistency(_cfg(block_pattern=("local", "global"), window=8,
                             softcap_attn=50.0, softcap_final=30.0,
                             post_norm=True, ffn_kind="geglu"))


def test_decode_mla():
    _decode_consistency(_cfg(head_dim=32, mla=MLAConfig(32, 16, 8, 16)))


def test_decode_moe():
    # capacity is per-sequence-length; decode (S=1) routes every token,
    # prefill may drop -> compare with generous capacity
    cfg = _cfg(family="moe",
               moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                             capacity_factor=4.0))
    _decode_consistency(cfg)


def test_decode_xlstm():
    cfg = _cfg(family="ssm", d_ff=0, block_pattern=("m", "m", "m", "s"))
    _decode_consistency(cfg)


def test_decode_recurrentgemma():
    cfg = _cfg(family="hybrid", num_layers=5,
               block_pattern=("rec", "rec", "local"), window=8, lru_width=48)
    assert len(cfg.pattern_remainder) == 2    # exercises remainder blocks
    _decode_consistency(cfg)


def test_decode_mrope():
    cfg = _cfg(family="vlm", mrope_sections=(8, 4, 4), head_dim=32,
               embedding_inputs=True)
    _decode_consistency(cfg)


def test_decode_partial_rotary():
    _decode_consistency(_cfg(rotary_pct=0.5))


# ----------------------------------------------------- recurrent oracles
def test_rglru_matches_step_oracle():
    cfg = _cfg(lru_width=32)
    key = jax.random.PRNGKey(3)
    p = R.init_rglru(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 24, cfg.d_model))
    out, cache = R.rglru_apply(p, cfg, x, None)
    # step-by-step oracle
    c = R.init_rglru_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(24):
        o, c = R.rglru_apply(p, cfg, x[:, t:t + 1], None, cache=c, pos=t)
        outs.append(o)
    oracle = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(out, oracle, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(cache["state"], c["state"], rtol=1e-4,
                               atol=1e-4)


def test_mlstm_chunked_matches_step_oracle():
    cfg = _cfg(d_ff=0, num_layers=2, block_pattern=("m",))
    key = jax.random.PRNGKey(4)
    p = R.init_mlstm(key, cfg, jnp.float32)
    S = 32
    x = jax.random.normal(key, (2, S, cfg.d_model))
    out, cache = R.mlstm_apply(p, cfg, x, None)
    c = R.init_mlstm_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(S):
        o, c = R.mlstm_apply(p, cfg, x[:, t:t + 1], None, cache=c, pos=t)
        outs.append(o)
    oracle = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(out, oracle, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(cache["C"], c["C"], rtol=5e-4, atol=5e-4)


def test_slstm_decode_matches_scan():
    cfg = _cfg(d_ff=0, num_layers=1, block_pattern=("s",))
    key = jax.random.PRNGKey(5)
    p = R.init_slstm(key, cfg, jnp.float32)
    S = 16
    x = jax.random.normal(key, (2, S, cfg.d_model))
    out, _ = R.slstm_apply(p, cfg, x, None)
    c = R.init_slstm_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(S):
        o, c = R.slstm_apply(p, cfg, x[:, t:t + 1], None, cache=c, pos=t)
        outs.append(o)
    np.testing.assert_allclose(out, jnp.concatenate(outs, 1),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- MoE
def test_moe_respects_capacity_and_gates():
    cfg = _cfg(family="moe",
               moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                             capacity_factor=1.0))
    key = jax.random.PRNGKey(6)
    p = L.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    out, aux = L.moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert jnp.isfinite(aux) and aux >= 0
    # zero-capacity dropping must not produce NaN
    assert not jnp.any(jnp.isnan(out))


def test_moe_aux_loss_balanced_is_one_coef():
    """Perfectly uniform router -> aux == coef (E * (1/E) * 1)."""
    cfg = _cfg(family="moe",
               moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=16,
                             aux_loss_coef=0.01))
    key = jax.random.PRNGKey(7)
    p = L.init_moe(key, cfg, jnp.float32)
    p["router"] = jnp.zeros_like(p["router"])   # uniform probs
    x = jax.random.normal(key, (2, 64, cfg.d_model))
    _, aux = L.moe_apply(p, cfg, x)
    np.testing.assert_allclose(aux, 0.01, rtol=0.3)


# --------------------------------------------------------------- RoPE
def test_rope_preserves_norm():
    cfg = _cfg()
    key = jax.random.PRNGKey(8)
    x = jax.random.normal(key, (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y = L.apply_rope(x, pos, cfg)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    cfg = _cfg()
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))

    def score(i, j):
        qi = L.apply_rope(q, jnp.full((1, 1), i), cfg)
        kj = L.apply_rope(k, jnp.full((1, 1), j), cfg)
        return float(jnp.sum(qi * kj))

    assert abs(score(3, 1) - score(10, 8)) < 1e-4
    assert abs(score(5, 5) - score(0, 0)) < 1e-4
