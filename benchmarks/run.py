"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. The paper's quantities
(rounds-to-accuracy, iterations-to-accuracy, energy) appear in `derived`.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig2 --paper
"""
from __future__ import annotations

import argparse
import copy
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import obs
from repro.configs.base import CommConfig, ObsConfig, SchedConfig
from repro.metrics import energy

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
#: committed perf trajectory of the engine benchmark (baseline = the
#: pre-flat-resident tree engine; current = this checkout)
BENCH_ENGINE_JSON = os.path.join(ROOT, "BENCH_engine.json")
#: committed comm / sched benchmark rows — schema-validated `bench`
#: records (manifest first), regenerated through the recorder
BENCH_COMM_JSON = os.path.join(ROOT, "experiments", "bench_comm.json")
BENCH_SCHED_JSON = os.path.join(ROOT, "experiments", "bench_sched.json")
BENCH_ROBUST_JSON = os.path.join(ROOT, "experiments",
                                 "bench_robust.json")


def _row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _opt(rec: dict, **fields) -> dict:
    """Attach the non-None fields — records omit absent metrics
    instead of writing nulls the schema would reject."""
    for k, v in fields.items():
        if v is not None:
            rec[k] = v
    return rec


def _write_bench_records(path: str, rows: list, bench: str,
                         write: bool = True) -> None:
    """Emit benchmark rows through the recorder — every row validated
    against the obs schema at emit time, manifest header first — and
    (unless ``write=False``, the smoke path: validate only) commit
    them as the pretty JSON array under experiments/ that
    `tools/obs_report.py --validate` gates in CI and
    `tools/obs_diff.py` aligns by row name across checkouts."""
    rec = obs.RunRecorder(meta={"bench": bench})
    rec.emit_all(rows)
    rec.close()
    if not write:
        return
    with open(path, "w") as f:
        json.dump(rec.ring.records(), f, indent=1)
        f.write("\n")
    print(f"# wrote {len(rows)} bench records to {path}", flush=True)


#: the schema-registered engine columns a committed record keeps; the
#: in-run annotations (gate flags, ratios) stay in bench_results.json
_ENGINE_FIELDS = ("layout_ops", "us_per_round", "state_copy_bytes",
                  "resident_state_bytes")


def _engine_record(name: str, row: dict) -> dict:
    rec = {"record": "bench", "name": name}
    for f in _ENGINE_FIELDS:
        v = row.get(f)
        if v is not None:
            rec[f] = float(v) if f == "us_per_round" else int(v)
    return rec


def _load_engine_hist(data) -> dict:
    """The committed engine trajectory as ``{"baseline" | "current":
    {regime: row}}``.  The committed format is a JSON array of bench
    records named ``<group>/<regime>`` (manifest first); the legacy
    pre-v2 dict-of-dicts shape still loads for old checkouts."""
    if isinstance(data, dict):      # legacy {"baseline": {name: row}}
        return data
    hist: dict = {"baseline": {}, "current": {}}
    for r in data:
        if r.get("record") != "bench":
            continue
        group, _, name = r["name"].partition("/")
        hist.setdefault(group, {})[name] = r
    return hist


# ---------------------------------------------------------------- Fig. 2
def fig2_rounds_to_accuracy(paper_scale: bool, out: dict):
    """Test accuracy vs communication rounds: Fed-Sophia vs FedAvg vs DONE
    on {MNIST, FMNIST} x {MLP, CNN} (paper Fig. 2)."""
    clients = 32 if paper_scale else 6
    rounds = 60 if paper_scale else 14
    models = ("mlp", "cnn")
    for model in models:
        for dataset in ("mnist", "fmnist"):
            curves = {}
            for opt in ("fed_sophia", "fedavg", "done"):
                # DONE diverges on the CNN (non-convex; see §Repro note) —
                # cap its rounds to bound the CPU budget
                r_opt = min(rounds, 8) if (opt == "done" and model == "cnn") \
                    else rounds
                res = common.run_federated(
                    model, dataset, opt, clients=clients, rounds=r_opt,
                    local_iters=10 if opt != "done" else 1)
                curves[opt] = res
                _row(f"fig2/{model}/{dataset}/{opt}",
                     res.seconds_per_round * 1e6,
                     f"rounds_to_75={res.rounds_to_target}"
                     f";final_acc={res.accs[-1]:.3f}")
            out[f"fig2/{model}/{dataset}"] = {
                k: {"accs": v.accs, "rounds_to_75": v.rounds_to_target}
                for k, v in curves.items()}


# ---------------------------------------------------------------- Fig. 3
def fig3_total_iterations(paper_scale: bool, out: dict):
    """Accuracy vs TOTAL local iterations (compute cost view, Fig. 3).
    DONE runs many Richardson iterations per round -> worse iteration
    efficiency; derived reports iterations to 75%."""
    clients = 32 if paper_scale else 6
    for dataset in ("mnist", "fmnist"):
        for opt, iters_per_round in (("fed_sophia", 10), ("fedavg", 10),
                                     ("done", 25)):
            res = common.run_federated(
                "mlp", dataset, opt, clients=clients, rounds=14,
                local_iters=10 if opt != "done" else 1)
            per_round = iters_per_round
            it_to = (res.rounds_to_target * per_round
                     if res.rounds_to_target else None)
            _row(f"fig3/mlp/{dataset}/{opt}",
                 res.seconds_per_round * 1e6,
                 f"iters_to_75={it_to};final_acc={res.accs[-1]:.3f}")
            out[f"fig3/mlp/{dataset}/{opt}"] = {
                "iters_to_75": it_to, "accs": res.accs}


# --------------------------------------------------------------- Table I
def table1_hyperparams(paper_scale: bool, out: dict):
    """lr x local-iteration sweep for Fed-Sophia, FMNIST + CNN."""
    clients = 32 if paper_scale else 6
    rows = []
    for lr in (0.01, 0.003, 0.0005):
        res = common.run_federated("cnn", "fmnist", "fed_sophia",
                                   clients=clients, rounds=12,
                                   local_iters=10, lr=lr)
        rows.append((lr, 10, res.accs[-1]))
        _row(f"table1/lr={lr}/J=10", res.seconds_per_round * 1e6,
             f"test_acc={res.accs[-1]:.3f}")
    for J in (1, 5, 10):
        res = common.run_federated("cnn", "fmnist", "fed_sophia",
                                   clients=clients, rounds=12,
                                   local_iters=J, lr=0.001)
        rows.append((0.001, J, res.accs[-1]))
        _row(f"table1/lr=0.001/J={J}", res.seconds_per_round * 1e6,
             f"test_acc={res.accs[-1]:.3f}")
    out["table1"] = rows


# -------------------------------------------------------------- Table II
def table2_energy(paper_scale: bool, out: dict):
    """Computation/communication energy to a 75% target (MNIST + CNN),
    via the paper's Eq. 13-14 channel model."""
    clients = 32 if paper_scale else 6
    n_params = common.num_params("cnn")
    fl = common.flops_per_local_iter("cnn")
    res = {}
    for opt, J, hess in (("done", 1, 0), ("fedavg", 10, 0),
                         ("fed_sophia", 10, 2)):
        r = common.run_federated("cnn", "mnist", opt, clients=clients,
                                 rounds=16, local_iters=J)
        rounds = r.rounds_to_target or 16
        # DONE: Richardson+power iterations cost ~2x a fwd+bwd each (HVPs)
        flops_iter = fl * (45 if opt == "done" else 1)
        e = energy.round_energy(n_params, flops_iter, J, hessian_iters=hess)
        total = {k: v * rounds for k, v in e.items()}
        res[opt] = {"rounds_to_75": rounds, **total,
                    "kg_co2": energy.footprint_kg_co2(total["total_J"])}
        _row(f"table2/{opt}", r.seconds_per_round * 1e6,
             f"rounds={rounds};comp_J={total['compute_J']:.3g}"
             f";comm_J={total['comm_J']:.3g}"
             f";co2_kg={res[opt]['kg_co2']:.3g}")
    out["table2"] = res


# ------------------------------------------------------------- Fig. comm
def fig_comm_bytes(paper_scale: bool, out: dict):
    """Accuracy vs bytes on the wire: Fed-Sophia on the MNIST-synthetic
    CNN under each compression regime at a matched round count.

    Reports every stream (uplink + downlink + curvature) per round and
    the TOTAL reduction vs the uncompressed baseline, plus the
    bytes-to-target-accuracy x-axis (methodology: benchmarks/README.md).
    The `bidir-*` regimes compress all three streams; acceptance for
    the bidirectional layer is >= 3x total reduction at matched rounds.
    """
    clients = 32 if paper_scale else 6
    rounds = 16
    comms = {
        "identity": CommConfig(),
        "int8": CommConfig(compressor="int8"),
        "int4": CommConfig(compressor="int4"),
        "topk": CommConfig(compressor="topk", topk_ratio=0.05),
        "signsgd": CommConfig(compressor="signsgd"),
        # bidirectional: compressed broadcast + hessian-EMA stream
        "bidir-int8": CommConfig(compressor="int8",
                                 downlink_compressor="int8",
                                 hessian_compressor="int4"),
        "bidir-int4": CommConfig(compressor="int4",
                                 downlink_compressor="int8",
                                 hessian_compressor="int4"),
    }
    base_total = None
    recs = []
    for name, comm in comms.items():
        res = common.run_federated("cnn", "mnist", "fed_sophia",
                                   clients=clients, rounds=rounds,
                                   local_iters=10, comm=comm)
        if base_total is None:
            base_total = res.total_bytes_per_round
        ratio = base_total / res.total_bytes_per_round
        _row(f"comm/cnn/mnist/{name}", res.seconds_per_round * 1e6,
             f"uplink_B_per_round={res.uplink_bytes_per_round}"
             f";downlink_B_per_round={res.downlink_bytes_per_round}"
             f";hessian_B_per_round={res.hessian_bytes_per_round}"
             f";total_B_per_round={res.total_bytes_per_round}"
             f";total_reduction_x={ratio:.2f}"
             f";bytes_to_75={res.bytes_to_target}"
             f";final_acc={res.accs[-1]:.3f}")
        out[f"comm/cnn/mnist/{name}"] = {
            "uplink_bytes_per_round": res.uplink_bytes_per_round,
            "downlink_bytes_per_round": res.downlink_bytes_per_round,
            "hessian_bytes_per_round": res.hessian_bytes_per_round,
            "total_bytes_per_round": res.total_bytes_per_round,
            "total_reduction_x": ratio,
            "bytes_to_75": res.bytes_to_target,
            "accs": res.accs,
        }
        recs.append(_opt(
            {"record": "bench", "name": f"comm/cnn/mnist/{name}",
             "uplink_bytes": int(res.uplink_bytes_per_round),
             "downlink_bytes": int(res.downlink_bytes_per_round),
             "hessian_bytes": int(res.hessian_bytes_per_round),
             "total_bytes": int(res.total_bytes_per_round),
             "reduction_x": float(ratio),
             "accs": [float(a) for a in res.accs]},
            bytes_to_target=None if res.bytes_to_target is None
            else int(res.bytes_to_target)))
    _write_bench_records(BENCH_COMM_JSON, recs, "comm")


# ------------------------------------------------------------ Fig. sched
def fig_sched(paper_scale: bool, out: dict, smoke: bool = False):
    """Simulated wall-clock to a target loss: sync vs semisync vs async
    (repro.sched) under a straggler latency profile, MLP on the
    MNIST-synthetic task with int8 uplinks.

    The sync run fixes the target (its eval loss 60% through its round
    budget — a mid-run loss every discipline can reach); semisync and
    async get a larger aggregation-event budget but stop at the target
    — the straggler makes every sync round cost ~slowdown x the base
    latency, so buffered/async aggregation reaches the same loss in
    far less simulated time.  Acceptance: semisync or async reaches
    the sync target with ``speedup_x > 1``, with per-discipline byte
    totals reported alongside.  ``--smoke`` shrinks everything to a
    CI-sized run (same code path, no acceptance claim).
    """
    clients = 32 if paper_scale else (4 if smoke else 6)
    events = 2 if smoke else 14
    comm = CommConfig(compressor="int8")
    profile = dict(latency_profile="straggler", straggler_frac=0.25,
                   straggler_slowdown=10.0)
    runs = {
        "sync": (SchedConfig(discipline="sync", **profile), events),
        "semisync": (SchedConfig(discipline="semisync",
                                 buffer_size=max(1, clients // 2),
                                 **profile),
                     2 * events if smoke else 4 * events),
        "async": (SchedConfig(discipline="async", staleness_power=0.5,
                              **profile),
                  2 * clients * events if not smoke else 3 * events),
    }
    target = None
    sync_t = None
    recs = []
    for name, (sched, budget) in runs.items():
        res = common.run_scheduled(
            "mlp", "mnist", "fed_sophia", sched=sched, events=budget,
            clients=clients, local_iters=5, comm=comm,
            target_loss=target, stop_at_target=target is not None)
        trace = res.trace
        if name == "sync":
            # target: the loss 60% through the sync budget — a mid-run
            # loss every discipline can reach within its own budget
            mid = trace.events[max(0, int(0.6 * len(trace.events)) - 1)]
            target = mid.eval_loss
            sync_t = trace.time_to_target(target)
        t_target = trace.time_to_target(target)
        b_target = trace.bytes_to_target(target)
        speedup = (sync_t / t_target) if t_target else None
        max_stale = max((max(e.staleness) for e in trace.events
                         if e.staleness), default=0)
        _row(f"sched/mlp/mnist/straggler/{name}",
             res.seconds_per_event * 1e6,
             f"sim_s_to_target={t_target if t_target else None}"
             f";bytes_to_target={b_target}"
             f";speedup_x={f'{speedup:.2f}' if speedup else None}"
             f";events={len(trace.events)}"
             f";max_staleness={max_stale}"
             f";final_loss={trace.events[-1].eval_loss:.4f}")
        out[f"sched/mlp/mnist/straggler/{name}"] = {
            "target_loss": target,
            "sim_seconds_to_target": t_target,
            "bytes_to_target": b_target,
            "speedup_x": speedup,
            "events": len(trace.events),
            "max_staleness": int(max_stale),
            "times": [e.time for e in trace.events],
            "eval_losses": [e.eval_loss for e in trace.events],
            "cum_bytes": [e.cum_bytes for e in trace.events],
        }
        recs.append(_opt(
            {"record": "bench",
             "name": f"sched/mlp/mnist/straggler/{name}",
             "target_loss": float(target),
             "events": len(trace.events),
             "max_staleness": int(max_stale),
             "event_times_s": [float(e.time) for e in trace.events],
             "event_eval_losses": [float(e.eval_loss)
                                   for e in trace.events],
             "event_cum_bytes": [int(e.cum_bytes)
                                 for e in trace.events]},
            sim_s_to_target=float(t_target) if t_target else None,
            bytes_to_target=None if b_target is None else int(b_target),
            speedup_x=float(speedup) if speedup else None))
    # --smoke validates the record construction path without touching
    # the committed rows (its budgets are CI-sized, not the benchmark)
    _write_bench_records(BENCH_SCHED_JSON, recs, "sched",
                         write=not smoke)


# ------------------------------------------------------ adversarial fleet
def fig_robust(paper_scale: bool, out: dict, smoke: bool = False):
    """Bytes-to-target under an adversarial fleet (docs/robustness.md):
    IID vs Dirichlet(0.1) label skew, 0% vs 20% sign-flip byzantine,
    mean vs trimmed-mean vs coordinate-median aggregation, MLP on the
    MNIST-synthetic task.

    The benign run on the SAME Dirichlet(0.1) partition fixes the
    target: its eval loss 20% through the round budget.  Headline:
    under 20% sign-flip byzantine clients, plain mean never recovers
    that benign-skew trajectory within the full budget while trimmed
    mean and coordinate median do — ``bytes_to_target`` prices the
    defence.  ``--smoke`` shrinks the budgets (same code path, no
    acceptance claim)."""
    from repro.configs.base import RobustConfig
    clients = 32 if paper_scale else 8
    rounds = 3 if smoke else 24
    byz = dict(attack="sign_flip", attack_fraction=0.2)
    iid, dir01 = 100.0, 0.1
    regimes = [
        ("iid/clean/mean", iid, RobustConfig()),
        ("dir01/clean/mean", dir01, RobustConfig()),
        ("dir01/byz20/mean", dir01, RobustConfig(**byz)),
        ("dir01/byz20/trimmed_mean", dir01,
         RobustConfig(aggregator="trimmed_mean", trim_fraction=0.25,
                      **byz)),
        ("dir01/byz20/coordinate_median", dir01,
         RobustConfig(aggregator="coordinate_median", **byz)),
        ("iid/byz20/trimmed_mean", iid,
         RobustConfig(aggregator="trimmed_mean", trim_fraction=0.25,
                      **byz)),
    ]
    target = None
    recs = []
    results = []
    for name, alpha, robust in regimes:
        results.append((name, common.run_robust(
            "mlp", "mnist", "fed_sophia", robust=robust, alpha=alpha,
            clients=clients, rounds=rounds, local_iters=5)))
        if name == "dir01/clean/mean":
            # the benign run on the same skewed partition fixes the
            # bar: its eval loss 20% through the budget — robustness
            # means recovering the benign-skew trajectory under attack
            target = float(results[-1][1].eval_losses[
                min(rounds - 1, int(0.2 * rounds))])
    for name, res in results:
        b_target = res.bytes_to_loss(target)
        full = f"robust/mlp/mnist/{name}"
        _row(full, res.seconds_per_round * 1e6,
             f"target_loss={target:.4f}"
             f";bytes_to_target={b_target}"
             f";final_eval_loss={res.eval_losses[-1]:.4f}")
        out[full] = {
            "target_loss": target,
            "bytes_to_target": b_target,
            "eval_losses": res.eval_losses,
            "final_eval_loss": res.eval_losses[-1],
            "total_bytes_per_round": res.total_bytes_per_round,
        }
        recs.append(_opt(
            {"record": "bench", "name": full,
             "target_loss": float(target),
             "total_bytes": int(res.total_bytes_per_round),
             "event_eval_losses": [float(v) for v in res.eval_losses],
             "event_cum_bytes": [
                 (r + 1) * int(res.total_bytes_per_round)
                 for r in range(len(res.eval_losses))]},
            bytes_to_target=None if b_target is None else int(b_target)))
    if not smoke:
        # the headline ordering the committed rows must show: robust
        # aggregation recovers under attack, plain mean does not
        reached = {n: out[f"robust/mlp/mnist/{n}"]["bytes_to_target"]
                   for n, _, _ in regimes}
        assert reached["dir01/byz20/mean"] is None, \
            "plain mean reached the target under 20% sign-flip"
        for n in ("dir01/byz20/trimmed_mean",
                  "dir01/byz20/coordinate_median"):
            assert reached[n] is not None, \
                f"{n} failed to reach the target under attack"
    _write_bench_records(BENCH_ROBUST_JSON, recs, "robust",
                         write=not smoke)


# ----------------------------------------------------- engine micro-bench
#: jaxpr primitives that implement layout conversion between the pytree
#: and the packed (rows, cols) wire buffer: pack = concatenate (+pad),
#: unpack = slice-of-flat.  dynamic_slice covers scan-carried variants.
LAYOUT_PRIMS = frozenset({"concatenate", "slice", "dynamic_slice", "pad"})


def _iter_subjaxprs(v):
    """Yield every Jaxpr nested in an eqn param (scan/cond/pjit/...)."""
    if hasattr(v, "eqns"):              # Jaxpr
        yield v
    elif hasattr(v, "jaxpr"):           # ClosedJaxpr
        yield v.jaxpr
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _iter_subjaxprs(x)


def _count_layout_ops(jaxpr) -> int:
    """Static count of layout-conversion ops in a jaxpr, recursively
    (a scan body is counted once — the static-op proxy for per-round
    conversion traffic; methodology in benchmarks/README.md)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in LAYOUT_PRIMS:
            n += 1
        for v in eqn.params.values():
            for sub in _iter_subjaxprs(v):
                n += _count_layout_ops(sub)
    return n


def _sched_dispatch_donation_check(task, key, batches, regressions):
    """End-to-end donation gate for the event-loop scheduler: the
    apply jit receives the donated state PLUS the donated stacked
    wire/stat/client-state-row buffers of one aggregation, and must
    still alias every resident-state byte in place (state_copy_B ==
    0).  A lost ``donate_argnums`` entry or an aliasing-defeating
    reshape in `VirtualScheduler._apply_impl` shows up here as nonzero
    copied bytes.  Static property of the compiled program — identical
    in --smoke and full runs; nothing is executed."""
    import jax as _jax
    from repro.core.fed import FedEngine
    from repro.sched.scheduler import VirtualScheduler

    comm = CommConfig(compressor="int8", use_pallas=True)
    fed = common.make_fed("fed_sophia", clients=4, local_iters=2,
                          lr=0.02, tau=2, rounds=4, comm=comm)
    fed = dataclasses.replace(
        fed, sched=SchedConfig(discipline="semisync", buffer_size=2))
    engine = FedEngine(task, fed)
    state = engine.pack_state(engine.init(_jax.random.fold_in(key, 5)))
    sch = VirtualScheduler(engine, lambda v: batches, donate=True)
    K = sch.buffer_size
    R, C = state["params"].shape

    def rows(x):
        # dispatch outputs arrive in the fp32 compute dtype; the apply
        # step downcasts on scatter (`FedEngine._store*`)
        return jnp.zeros((K,) + x.shape[1:], jnp.float32)

    opt_rows = (_jax.tree.map(rows, state["client_opt"])
                if "client_opt" in state else None)
    ef_rows = rows(state["comm_ef"]) if "comm_ef" in state else None
    compiled = sch._apply_fn.lower(
        state, jnp.zeros((K, R, C), jnp.float32),
        jnp.zeros((K,), jnp.float32), jnp.ones((K,), jnp.float32),
        jnp.arange(K, dtype=jnp.int32), ef_rows, opt_rows, None,
        None).compile()
    resident = sum(l.size * l.dtype.itemsize
                   for l in _jax.tree.leaves(state))
    ma = compiled.memory_analysis()
    aliased = int(getattr(ma, "alias_size_in_bytes", 0) or 0)
    copy_bytes = max(0, resident - aliased)
    _row("engine/mlp/sched-dispatch-donation", 0.0,
         f"resident_state_B={resident};state_copy_B={copy_bytes}")
    if copy_bytes:
        regressions.append(
            f"sched-dispatch-donation: the donated apply step left "
            f"{copy_bytes} bytes of resident state copied per "
            f"aggregation (want 0 — state and the stacked row buffers "
            f"aliased in place)")


def fig_engine(paper_scale: bool, out: dict, smoke: bool = False):
    """Round-engine microbenchmark: per-round wall-clock (jitted,
    block_until_ready), the layout-conversion op count of the round
    jaxpr, and the state-residency accounting of the compiled round,
    per comm regime.

    The `*-pallas` regimes are the production kernel path and the
    gated set: the fused kernels consume the packed (rows, cols)
    buffer, so every pytree<->flat conversion around them is pure HBM
    churn.  The `packed-donated-*` regimes additionally keep
    ``state["params"]`` packed BETWEEN rounds and donate the state to
    the jit — gated on ``state_copy_bytes == 0`` (XLA aliases every
    resident buffer in place; from `compiled.memory_analysis()`), the
    bf16 regime on ``resident_state_bytes`` ≤ 0.55x its fp32 twin
    (`CommConfig.state_dtype`), and the fp8 regime (bf16 params, e4m3
    moments, e5m2 hessian via `moment_dtype`/`hessian_dtype`) on ≤
    0.30x — plus the same in-run ref-gap band as the int8 kernel path.
    The scheduler's end-to-end donation (dispatch batches + apply-side
    stacked buffers) is gated alongside by
    `_sched_dispatch_donation_check`.  Results append to the committed perf
    trajectory in BENCH_engine.json — schema-validated ``bench``
    records named ``baseline/<regime>`` (the pre-flat-resident tree
    engine, frozen) and ``current/<regime>`` (this checkout) — and the
    run FAILS if a gated regime's op count (or a residency gate)
    regresses — `make bench-engine-smoke` runs the same gates in CI
    (`--smoke`: few-iteration timing, no file write).  Wall-clock
    drift is gated too, tolerance-banded: a gated regime failing
    ``us_per_round <= REPRO_US_BAND x committed`` (default band 2.5 —
    it catches a lost donation, an un-jitted round, or a fallback from
    the client-batched kernel launches to per-client ones, not machine
    jitter) fails the run.  The kernel path is additionally gated
    AGAINST THE REFERENCE within the same run: ``uplink-int8-pallas``
    must finish within ``REPRO_REF_GAP`` x ``uplink-int8-ref``
    (default 1.25) — the batched (C, rows, cols) launches are what
    make interpret-mode kernels competitive with pure JAX, and this
    gate pins that win.
    """
    clients = 8 if paper_scale else 4
    # --smoke now times a few iterations too: the us_per_round
    # tolerance-band gate below needs a current number to compare
    # against the committed trajectory
    iters = 3 if smoke else (20 if not paper_scale else 5)
    # regime -> (comm config, fed.use_pallas, gated, packed, donate,
    # probes): op-count acceptance applies to the kernel path; the
    # `-ref` regime tracks the pure-JAX wall-clock alongside.
    regimes = {
        "direct-pallas": (CommConfig(use_pallas=True), True, True,
                          False, False, False),
        "uplink-int8-pallas": (
            CommConfig(compressor="int8", use_pallas=True), True, True,
            False, False, False),
        "bidir-int8-pallas": (
            CommConfig(compressor="int8", downlink_compressor="int8",
                       hessian_compressor="int4", use_pallas=True),
            True, True, False, False, False),
        "uplink-int8-ref": (CommConfig(compressor="int8"), False, False,
                            False, False, False),
        # device-residency regimes: params packed between rounds,
        # state donated to the jit (in-place resident buffers)
        "packed-donated-pallas": (
            CommConfig(use_pallas=True), True, True, True, True, False),
        "packed-donated-int8-pallas": (
            CommConfig(compressor="int8", use_pallas=True), True, True,
            True, True, False),
        "packed-donated-bf16-pallas": (
            CommConfig(use_pallas=True, state_dtype="bfloat16"), True,
            True, True, True, False),
        # Sophia health probes ON (repro.obs.probes): must keep the
        # layout-op count and donation contract of its probes-off twin
        "packed-donated-probes-pallas": (
            CommConfig(use_pallas=True), True, True, True, True, True),
        # robustness layer present but DEGENERATE (trimmed_mean at trim
        # 0 resolves to "mean" — docs/robustness.md): must keep the
        # layout-op count and donation contract of its robust-off twin
        "packed-donated-robustoff-pallas": (
            CommConfig(use_pallas=True), True, True, True, True, False),
        # fp8 residency frontier: bf16 params + e4m3 moments + e5m2
        # hessian EMA (per-buffer resident dtypes) — the (C, rows,
        # cols) Sophia stacks dominate resident state, so quartering
        # them gates at <= 0.30x the fp32 twin below
        "packed-donated-fp8-pallas": (
            CommConfig(compressor="int8", use_pallas=True,
                       state_dtype="bfloat16",
                       moment_dtype="float8_e4m3fn",
                       hessian_dtype="float8_e5m2"),
            True, True, True, True, False),
    }
    import jax as _jax
    from repro.core.fed import FedEngine
    from repro.data import synthetic as syn

    key = _jax.random.PRNGKey(0)
    x, y = syn.make_image_data(key, 2048, "mnist", noise=1.3)
    part = syn.dirichlet_partition(_jax.random.fold_in(key, 1), y,
                                   clients, alpha=0.5)
    tr, _ = syn.train_test_split(part)
    task = common.make_task("mlp")
    batches = syn.client_batches(_jax.random.fold_in(key, 2), x, y, tr, 32)
    rng = _jax.random.fold_in(key, 3)

    results = {}
    for name, (comm, use_pallas, gated, packed, donate,
               probes) in regimes.items():
        fed = common.make_fed("fed_sophia", clients=clients, local_iters=3,
                              lr=0.02, tau=2, rounds=16, comm=comm)
        fed = dataclasses.replace(fed, use_pallas=use_pallas,
                                  obs=ObsConfig(probes=probes))
        if "robustoff" in name:
            from repro.configs.base import RobustConfig
            fed = dataclasses.replace(fed, robust=RobustConfig(
                aggregator="trimmed_mean", trim_fraction=0.0))
        engine = FedEngine(task, fed)
        state = engine.init(_jax.random.fold_in(key, 4))
        if packed:
            state = engine.pack_state(state)
        ops = _count_layout_ops(
            _jax.make_jaxpr(engine.round)(state, batches, rng).jaxpr)
        # state-residency accounting: resident bytes are the whole
        # state dict (params + m/h + EF + replicas); under donation
        # XLA aliases them onto the outputs in place, so per-round
        # copies = resident - aliased (0 when donation covers all)
        resident = sum(l.size * l.dtype.itemsize
                       for l in _jax.tree.leaves(state))
        # one AOT compile serves both the memory analysis and the
        # timed loop (jit __call__ would otherwise compile a second
        # copy of the same program)
        compiled = engine.round_fn(donate=donate).lower(
            state, batches, rng).compile()
        ma = compiled.memory_analysis()
        aliased = int(getattr(ma, "alias_size_in_bytes", 0) or 0)
        copy_bytes = max(0, resident - aliased)
        us = None
        if iters:
            s, m = compiled(state, batches, rng)          # warm-up
            _jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
            for _ in range(iters):
                # donated calls consume their input state: re-thread it
                s, m = compiled(s, batches, rng)
                _jax.block_until_ready(m["loss"])
            us = (time.perf_counter() - t0) / iters * 1e6
        results[name] = {"layout_ops": ops, "us_per_round": us,
                         "gated": gated, "packed": packed,
                         "donate": donate, "probes": probes,
                         "state_dtype": comm.state_dtype,
                         "resident_state_bytes": resident,
                         "aliased_bytes": aliased,
                         "state_copy_bytes": copy_bytes}
        # every row doubles as a schema-validated obs `bench` record
        obs.validate_record(_engine_record(name, results[name]))

    hist = {}
    if os.path.exists(BENCH_ENGINE_JSON):
        with open(BENCH_ENGINE_JSON) as f:
            hist = _load_engine_hist(json.load(f))
    elif smoke:
        # the smoke run exists to gate against the COMMITTED trajectory;
        # without it the comparison degenerates to self-vs-self and CI
        # would report success while gating nothing
        raise SystemExit(
            f"engine benchmark --smoke: {BENCH_ENGINE_JSON} is missing — "
            f"run the full `--only engine` benchmark once and commit the "
            f"trajectory before enabling the gate")
    # bootstrap (first-ever full run): this run becomes the frozen
    # baseline — deep-copied so the per-regime annotations below don't
    # leak into the stored baseline
    baseline = hist.get("baseline") or copy.deepcopy(results)
    committed = hist.get("current") or baseline

    # wall-clock drift band (ROADMAP §2): a gated regime's current
    # us_per_round may not exceed REPRO_US_BAND x the committed
    # trajectory's timing.  The band is loose enough to absorb CI
    # machine jitter but tight enough to catch a lost donation, an
    # un-jitted round, or a fallback from client-batched kernel
    # launches to per-client ones.  0 disables; skipped when either
    # side has no timing recorded.
    us_band = float(os.environ.get("REPRO_US_BAND", "2.5"))
    regressions = []
    for name, r in results.items():
        base_ops = baseline.get(name, {}).get("layout_ops", r["layout_ops"])
        gate_ops = committed.get(name, {}).get("layout_ops",
                                               r["layout_ops"])
        red = base_ops / r["layout_ops"] if r["layout_ops"] else float("inf")
        _row(f"engine/mlp/{name}",
             r["us_per_round"] if r["us_per_round"] else 0.0,
             f"layout_ops={r['layout_ops']}"
             f";baseline_ops={base_ops}"
             f";reduction_x={red:.2f}"
             f";resident_state_B={r['resident_state_bytes']}"
             f";state_copy_B={r['state_copy_bytes']}")
        r["baseline_layout_ops"] = base_ops
        r["reduction_x"] = red
        if r["gated"] and r["layout_ops"] > gate_ops:
            regressions.append(
                f"{name}: layout_ops {r['layout_ops']} > committed "
                f"{gate_ops}")
        gate_us = committed.get(name, {}).get("us_per_round")
        if (us_band > 0 and r["gated"] and r["us_per_round"] and gate_us
                and r["us_per_round"] > us_band * gate_us):
            regressions.append(
                f"{name}: us_per_round {r['us_per_round']:.0f} exceeds "
                f"{us_band:.1f}x the committed {gate_us:.0f} "
                f"(REPRO_US_BAND overrides the band)")
        # residency gates (static properties of the compiled round —
        # identical in --smoke and full runs)
        if r["donate"] and r["state_copy_bytes"] != 0:
            regressions.append(
                f"{name}: donation left {r['state_copy_bytes']} bytes "
                f"of resident state copied per round (want 0 — every "
                f"state buffer aliased in place)")
    # probes gate: enabling the Sophia health probes must not add a
    # single layout op vs the probes-off twin (probe math is
    # elementwise/reduction only — docs/observability.md)
    probed = results.get("packed-donated-probes-pallas")
    twin = results.get("packed-donated-pallas")
    if probed and twin and probed["layout_ops"] != twin["layout_ops"]:
        regressions.append(
            f"packed-donated-probes-pallas: layout_ops "
            f"{probed['layout_ops']} != probes-off twin "
            f"{twin['layout_ops']} (probes must stay layout-neutral)")
    # robust-off gate: a degenerate RobustConfig must leave the traced
    # round untouched — same layout-op count as the twin without the
    # robustness layer (the donation gate above already pins its
    # state_copy_bytes == 0)
    robustoff = results.get("packed-donated-robustoff-pallas")
    if robustoff and twin and robustoff["layout_ops"] != twin["layout_ops"]:
        regressions.append(
            f"packed-donated-robustoff-pallas: layout_ops "
            f"{robustoff['layout_ops']} != robust-off twin "
            f"{twin['layout_ops']} (degenerate robust parameterizations "
            f"must keep the mean path's traced graph)")
    # bf16 residency gate: the bf16 regime must roughly halve the
    # resident-state HBM of its fp32 twin
    bf16 = results.get("packed-donated-bf16-pallas")
    fp32 = results.get("packed-donated-pallas")
    if bf16 and fp32:
        ratio = (bf16["resident_state_bytes"]
                 / fp32["resident_state_bytes"])
        bf16["resident_ratio_vs_fp32"] = ratio
        if ratio > 0.55:
            regressions.append(
                f"packed-donated-bf16-pallas: resident state is "
                f"{ratio:.2f}x the fp32 twin (want <= 0.55x)")
    # fp8 residency gate: bf16 params + fp8 m/h must cut resident-state
    # HBM to about a quarter of the fp32 twin (the Sophia EMA stacks
    # are the dominant term, so the blend lands near 0.28x)
    fp8 = results.get("packed-donated-fp8-pallas")
    if fp8 and fp32:
        ratio = (fp8["resident_state_bytes"]
                 / fp32["resident_state_bytes"])
        fp8["resident_ratio_vs_fp32"] = ratio
        if ratio > 0.30:
            regressions.append(
                f"packed-donated-fp8-pallas: resident state is "
                f"{ratio:.2f}x the fp32 twin (want <= 0.30x)")
    # ref-gap gate: the kernel path must stay competitive with the
    # pure-JAX reference IN THE SAME RUN (both sides share the machine
    # and the load, so this ratio is jitter-immune in a way the
    # committed-trajectory band is not).  The client-batched (C, rows,
    # cols) launches are what close this gap — one grid over the whole
    # cohort instead of C interpreter passes — so a fallback to
    # per-client launches shows up here first.
    ref_gap = float(os.environ.get("REPRO_REF_GAP", "1.25"))
    kern = results.get("uplink-int8-pallas")
    ref = results.get("uplink-int8-ref")
    if (ref_gap > 0 and kern and ref and kern["us_per_round"]
            and ref["us_per_round"]):
        ratio = kern["us_per_round"] / ref["us_per_round"]
        kern["ref_gap_vs_int8_ref"] = ratio
        if ratio > ref_gap:
            regressions.append(
                f"uplink-int8-pallas: us_per_round is {ratio:.2f}x the "
                f"uplink-int8-ref regime in this run (want <= "
                f"{ref_gap:.2f}x; REPRO_REF_GAP overrides)")
    # the fp8 regime must pay for its quarter-HBM residency without
    # falling out of the same in-run band vs the pure-JAX reference
    # (narrow loads upcast in-VMEM; no extra HBM pass is allowed)
    if (ref_gap > 0 and fp8 and ref and fp8["us_per_round"]
            and ref["us_per_round"]):
        ratio = fp8["us_per_round"] / ref["us_per_round"]
        fp8["ref_gap_vs_int8_ref"] = ratio
        if ratio > ref_gap:
            regressions.append(
                f"packed-donated-fp8-pallas: us_per_round is "
                f"{ratio:.2f}x the uplink-int8-ref regime in this run "
                f"(want <= {ref_gap:.2f}x; REPRO_REF_GAP overrides)")
    _sched_dispatch_donation_check(task, key, batches, regressions)
    out["engine"] = results
    if regressions:
        # do NOT persist the regressed counts: rewriting 'current'
        # before failing would ratchet the gate down to the regressed
        # value and the next run would pass silently
        raise SystemExit(
            "engine benchmark: layout-conversion op count regressed:\n  "
            + "\n  ".join(regressions))
    if not smoke:
        _write_bench_records(
            BENCH_ENGINE_JSON,
            [_engine_record(f"{group}/{name}", r)
             for group, rows in (("baseline", baseline),
                                 ("current", results))
             for name, r in rows.items()],
            "engine")


# ----------------------------------------------------- kernel micro-bench
def bench_sophia_kernel(out: dict):
    """Fused Pallas Sophia step (interpret) vs pure-JAX reference."""
    from repro.core import sophia as core_sophia
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (1024, 1024))}
    grads = jax.tree.map(jnp.ones_like, params)
    st = core_sophia.init_state(params)
    h_hat = jax.tree.map(jnp.ones_like, params)
    kw = dict(lr=1e-3, beta1=0.9, beta2=0.95, rho=0.04, eps=1e-12,
              weight_decay=1e-4)
    for use_pallas, name in ((False, "ref"), (True, "pallas_interpret")):
        fn = jax.jit(lambda p, g, m, h, hh, _up=use_pallas:
                     core_sophia.sophia_step(
                         p, g, core_sophia.SophiaState(m, h), hh,
                         jnp.asarray(True), use_pallas=_up, **kw))
        fn(params, grads, st.m, st.h, h_hat)  # compile
        t0 = time.time()
        n = 10
        for _ in range(n):
            r = fn(params, grads, st.m, st.h, h_hat)
        jax.block_until_ready(jax.tree.leaves(r)[0])
        us = (time.time() - t0) / n * 1e6
        _row(f"kernel/sophia_step/{name}", us, "1M params")
        out[f"kernel/{name}_us"] = us


ALL = {
    "fig2": fig2_rounds_to_accuracy,
    "fig3": fig3_total_iterations,
    "table1": table1_hyperparams,
    "table2": table2_energy,
    "comm": fig_comm_bytes,
    "sched": fig_sched,
    "engine": fig_engine,
    "robust": fig_robust,
}

#: regimes that understand --smoke (tiny budgets / no timing, same
#: code path)
SMOKE_AWARE = ("sched", "engine", "robust")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="fig2|fig3|table1|table2|comm|sched|engine|"
                         "robust|kernel|all")
    ap.add_argument("--paper", action="store_true",
                    help="paper scale: 32 clients (slow on CPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized fast mode (sched/engine regimes: tiny "
                         "budgets / op counts only, same code path)")
    ap.add_argument("--out", default="experiments/bench_results.json")
    args = ap.parse_args()

    out: dict = {}
    print("name,us_per_call,derived")
    if args.only in ("kernel", "all"):
        bench_sophia_kernel(out)
    for name, fn in ALL.items():
        if args.only in (name, "all"):
            if name in SMOKE_AWARE:
                fn(args.paper, out, smoke=args.smoke)
            else:
                fn(args.paper, out)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
