"""Render the roofline table from dry-run JSON artifacts.

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
        [--mesh prod1pod] [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str, mesh: str | None = None, tag: str = ""):
    recs = []
    for fn in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        if (r.get("tag") or "") != tag:
            continue
        if r.get("optimizer", "fed_sophia") != "fed_sophia":
            continue
        recs.append(r)
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(recs, markdown=False):
    hdr = ["arch", "shape", "mesh", "compute", "memory", "collective",
           "bottleneck", "useful_flops", "temp_GiB"]
    rows = []
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    recs = sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9),
                                       r["mesh"]))
    for r in recs:
        if r["status"] == "skipped":
            rows.append([r["arch"], r["shape"], r["mesh"], "-", "-", "-",
                         f"SKIP: {r['reason'][:42]}", "-", "-"])
            continue
        if r["status"] != "ok":
            rows.append([r["arch"], r["shape"], r["mesh"], "-", "-", "-",
                         "ERROR", "-", "-"])
            continue
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        temp = r.get("memory", {}).get("temp_size_in_bytes")
        rows.append([
            r["arch"], r["shape"], r["mesh"],
            fmt_s(t["compute_s"]), fmt_s(t["memory_s"]),
            fmt_s(t["collective_s"]), t["bottleneck"],
            f"{ratio:.2f}" if ratio else "-",
            f"{temp / 2**30:.1f}" if temp else "-",
        ])
    if markdown:
        out = ["| " + " | ".join(hdr) + " |",
               "|" + "|".join(["---"] * len(hdr)) + "|"]
        out += ["| " + " | ".join(str(c) for c in row) + " |"
                for row in rows]
    else:
        w = [max(len(str(r[i])) for r in [hdr] + rows)
             for i in range(len(hdr))]
        out = ["  ".join(h.ljust(w[i]) for i, h in enumerate(hdr))]
        out += ["  ".join(str(c).ljust(w[i]) for i, c in enumerate(row))
                for row in rows]
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh, args.tag)
    print(table(recs, markdown=args.markdown))


if __name__ == "__main__":
    main()
