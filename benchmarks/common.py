"""Shared harness for the paper-reproduction benchmarks."""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import accounting as comm_accounting
from repro.configs.base import (CommConfig, FedConfig, RobustConfig,
                                SchedConfig)
from repro.core.fed import FedEngine
from repro.data import partition as dpart
from repro.data import synthetic as syn
from repro.models.small import CNNTask, MLPTask
from repro.sched import SchedTrace, VirtualScheduler

# CPU-feasible defaults; --paper flips to the paper's 32 clients.
N_SAMPLES = 8192
NOISE = {"mnist": 1.3, "fmnist": 1.8}


def make_task(model: str):
    return MLPTask(hidden=64) if model == "mlp" else CNNTask(channels=(8, 16))


def make_fed(optimizer: str, *, clients: int, local_iters: int, lr: float,
             tau: int = 5, rounds: int = 60,
             comm: Optional[CommConfig] = None) -> FedConfig:
    return FedConfig(num_clients=clients, local_iters=local_iters,
                     optimizer=optimizer, lr=lr, tau=tau,
                     total_rounds=rounds,
                     comm=comm if comm is not None else CommConfig())


DEFAULT_LR = {"fed_sophia": 0.02, "fedavg": 0.05, "done": 1.0,
              "fedadam": 0.02, "fedyogi": 0.02}


@dataclass
class RunResult:
    accs: List[float]          # test accuracy per round
    losses: List[float]
    rounds_to_target: Optional[int]
    seconds_per_round: float
    local_iters: int
    uplink_bytes_per_round: int = 0
    # exact cumulative bytes on the wire (ALL streams, both directions)
    # when the target accuracy was reached (None if never reached) —
    # the Fig. 3-style x-axis
    bytes_to_target: Optional[int] = None
    # per-stream per-round totals from repro.comm.accounting.round_bytes
    # (downlink + hessian streams; total_bytes sums every stream)
    downlink_bytes_per_round: int = 0
    hessian_bytes_per_round: int = 0
    total_bytes_per_round: int = 0


def run_federated(model: str, dataset: str, optimizer: str, *,
                  clients: int = 8, rounds: int = 40, local_iters: int = 10,
                  lr: Optional[float] = None, tau: int = 5,
                  batch: int = 64, target_acc: float = 0.75,
                  seed: int = 0, eval_every: int = 1,
                  comm: Optional[CommConfig] = None) -> RunResult:
    key = jax.random.PRNGKey(seed)
    x, y = syn.make_image_data(key, N_SAMPLES, dataset,
                               noise=NOISE[dataset])
    part = syn.dirichlet_partition(jax.random.fold_in(key, 1), y, clients,
                                   alpha=0.5)
    tr, te = syn.train_test_split(part)
    task = make_task(model)
    fed = make_fed(optimizer, clients=clients, local_iters=local_iters,
                   lr=lr if lr is not None else DEFAULT_LR[optimizer],
                   tau=tau, rounds=rounds, comm=comm)
    engine = FedEngine(task, fed)
    state = engine.init(jax.random.fold_in(key, 2))
    round_fn = jax.jit(engine.round)
    teb = syn.client_batches(jax.random.fold_in(key, 3), x, y, te, 128)
    acc_fn = jax.jit(lambda p: jnp.mean(jax.vmap(
        lambda b: task.accuracy(p, b))(teb)))
    # exact per-round per-stream bytes from the accounting model; the
    # obs record schema carries these as exact int64 columns
    n_params = num_params(model)
    wire = comm_accounting.round_bytes(fed.comm, n_params, clients)
    per_round_up = wire["uplink_bytes"]

    accs, losses = [], []
    rounds_to_target = None
    bytes_to_target = None
    t0 = time.time()
    for r in range(rounds):
        batches = syn.client_batches(jax.random.fold_in(key, 100 + r),
                                     x, y, tr, batch)
        state, metrics = round_fn(state, batches,
                                  jax.random.fold_in(key, 1000 + r))
        losses.append(float(metrics["loss"]))
        if r % eval_every == 0 or r == rounds - 1:
            acc = float(acc_fn(state["params"]))
            accs.append(acc)
            if rounds_to_target is None and acc >= target_acc:
                rounds_to_target = r + 1
                bytes_to_target = wire["total_bytes"] * (r + 1)
    dt = (time.time() - t0) / rounds
    return RunResult(accs=accs, losses=losses,
                     rounds_to_target=rounds_to_target,
                     seconds_per_round=dt, local_iters=local_iters,
                     uplink_bytes_per_round=per_round_up,
                     bytes_to_target=bytes_to_target,
                     downlink_bytes_per_round=wire["downlink_bytes"],
                     hessian_bytes_per_round=(
                         wire["hessian_uplink_bytes"]
                         + wire["hessian_downlink_bytes"]),
                     total_bytes_per_round=wire["total_bytes"])


@dataclass
class SchedRunResult:
    trace: SchedTrace          # the full virtual-clock event log
    final_eval_loss: float
    seconds_per_event: float   # REAL seconds (compute cost of the sim)


def run_scheduled(model: str, dataset: str, optimizer: str, *,
                  sched: SchedConfig, events: int, clients: int = 6,
                  local_iters: int = 10, lr: Optional[float] = None,
                  tau: int = 5, batch: int = 64, seed: int = 0,
                  comm: Optional[CommConfig] = None,
                  target_loss: Optional[float] = None,
                  stop_at_target: bool = False) -> SchedRunResult:
    """Run one virtual-time scheduled federation (repro.sched) and
    return its event trace: simulated wall-clock, exact cumulative
    wire bytes and held-out eval loss per aggregation event."""
    key = jax.random.PRNGKey(seed)
    x, y = syn.make_image_data(key, N_SAMPLES, dataset,
                               noise=NOISE[dataset])
    part = syn.dirichlet_partition(jax.random.fold_in(key, 1), y, clients,
                                   alpha=0.5)
    tr, te = syn.train_test_split(part)
    task = make_task(model)
    fed = dataclasses.replace(
        make_fed(optimizer, clients=clients, local_iters=local_iters,
                 lr=lr if lr is not None else DEFAULT_LR[optimizer],
                 tau=tau, rounds=events, comm=comm),
        sched=sched)
    engine = FedEngine(task, fed)
    state = engine.init(jax.random.fold_in(key, 2))
    teb = syn.client_batches(jax.random.fold_in(key, 3), x, y, te, 128)
    eval_fn = jax.jit(lambda p: jnp.mean(jax.vmap(
        lambda b: task.loss(p, b, None))(teb)))

    def batch_fn(v):
        return syn.client_batches(jax.random.fold_in(key, 100 + v),
                                  x, y, tr, batch)

    scheduler = VirtualScheduler(engine, batch_fn, eval_fn=eval_fn)
    t0 = time.time()
    state, trace = scheduler.run(state, events,
                                 jax.random.fold_in(key, 1000),
                                 target_loss=target_loss,
                                 stop_at_target=stop_at_target)
    dt = (time.time() - t0) / max(len(trace.events), 1)
    return SchedRunResult(trace=trace,
                          final_eval_loss=trace.events[-1].eval_loss,
                          seconds_per_event=dt)


@dataclass
class RobustRunResult:
    losses: List[float]            # train loss per round
    eval_losses: List[float]       # held-out eval loss per round
    total_bytes_per_round: int     # all streams, exact accounting
    seconds_per_round: float

    def bytes_to_loss(self, target: float) -> Optional[int]:
        """Cumulative wire bytes at the first round whose eval loss
        reached ``target`` (None if the run never got there)."""
        for r, ls in enumerate(self.eval_losses):
            if ls <= target:
                return (r + 1) * self.total_bytes_per_round
        return None


def run_robust(model: str, dataset: str, optimizer: str, *,
               robust: RobustConfig, alpha: float, clients: int = 8,
               rounds: int = 30, local_iters: int = 10,
               lr: Optional[float] = None, tau: int = 5,
               batch: int = 64, seed: int = 0,
               comm: Optional[CommConfig] = None) -> RobustRunResult:
    """One synchronous adversarial-fleet run (docs/robustness.md):
    Dirichlet(alpha) label-skewed clients (`repro.data.partition`,
    equalized to the engine's fixed (C, n_per) matrix), byzantine /
    label-noise faults and robust aggregation from ``robust``, eval
    loss on a held-out split every round."""
    key = jax.random.PRNGKey(seed)
    x, y = syn.make_image_data(key, N_SAMPLES, dataset,
                               noise=NOISE[dataset])
    ragged = dpart.dirichlet_label_partition(np.asarray(y), clients,
                                             alpha, seed)
    part = dpart.equalize(ragged, N_SAMPLES // clients, seed)
    tr, te = syn.train_test_split(part)
    task = make_task(model)
    fed = dataclasses.replace(
        make_fed(optimizer, clients=clients, local_iters=local_iters,
                 lr=lr if lr is not None else DEFAULT_LR[optimizer],
                 tau=tau, rounds=rounds, comm=comm),
        robust=robust)
    engine = FedEngine(task, fed)
    state = engine.init(jax.random.fold_in(key, 2))
    round_fn = jax.jit(engine.round)
    teb = syn.client_batches(jax.random.fold_in(key, 3), x, y, te, 128)
    eval_fn = jax.jit(lambda p: jnp.mean(jax.vmap(
        lambda b: task.loss(p, b, None))(teb)))
    wire = comm_accounting.round_bytes(fed.comm, num_params(model),
                                       clients)
    noisy = None
    if robust.label_noise_fraction > 0.0:
        from repro.robust import attacks as robust_attacks
        noisy = robust_attacks.label_noise_mask(robust, clients)

    losses, eval_losses = [], []
    t0 = time.time()
    for r in range(rounds):
        batches = syn.client_batches(jax.random.fold_in(key, 100 + r),
                                     x, y, tr, batch)
        if noisy is not None and noisy.any():
            from repro.robust import attacks as robust_attacks
            batches = dict(batches, y=jnp.asarray(
                robust_attacks.corrupt_labels(robust, batches["y"],
                                              noisy, syn.NUM_CLASSES)))
        state, metrics = round_fn(state, batches,
                                  jax.random.fold_in(key, 1000 + r))
        losses.append(float(metrics["loss"]))
        eval_losses.append(float(eval_fn(state["params"])))
    dt = (time.time() - t0) / max(rounds, 1)
    return RobustRunResult(losses=losses, eval_losses=eval_losses,
                           total_bytes_per_round=wire["total_bytes"],
                           seconds_per_round=dt)


def flops_per_local_iter(model: str, batch: int = 64) -> float:
    """Forward+backward FLOPs for one local iteration (energy model)."""
    task = make_task(model)
    params = jax.eval_shape(task.init, jax.random.PRNGKey(0))
    n = sum(int(jnp.prod(jnp.array(p.shape))) for p in
            jax.tree.leaves(params))
    return 6.0 * n * batch


def num_params(model: str) -> int:
    task = make_task(model)
    params = jax.eval_shape(task.init, jax.random.PRNGKey(0))
    return sum(int(jnp.prod(jnp.array(p.shape)))
               for p in jax.tree.leaves(params))
