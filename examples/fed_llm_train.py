"""End-to-end driver: federated Fed-Sophia pre-training of a ~100M-param
decoder LM (minicpm-family reduced) on a synthetic token stream.

Default runs a ~100M model for 100 rounds x 3 local iterations = 300
local steps on CPU. Use --small for a quick functional check.

    PYTHONPATH=src python examples/fed_llm_train.py --small
    PYTHONPATH=src python examples/fed_llm_train.py          # ~100M run
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import ckpt
from repro.configs.base import FedConfig
from repro.core.fed import FedEngine
from repro.data import synthetic as syn
from repro.models import transformer as T


def build_cfg(small: bool):
    base = configs.get_model_config("minicpm-2b")
    if small:
        return base.reduced(d_model=128)
    # ~100M-param member of the same family (depth-scaled residuals, WSD)
    return dataclasses.replace(
        base.reduced(num_layers=8, d_model=512),
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=8,
        head_dim=64, d_ff=1536, vocab_size=32768, dtype="float32",
        residual_scale=1.4 / (8 ** 0.5))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-iters", type=int, default=3)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="experiments/fed_llm_ckpt")
    args = ap.parse_args()
    if args.small:
        args.rounds, args.seq, args.batch = 5, 64, 2

    cfg = build_cfg(args.small)
    task = T.LMTask(cfg)
    fed = FedConfig(num_clients=args.clients, local_iters=args.local_iters,
                    optimizer="fed_sophia", lr=args.lr, tau=5,
                    schedule="wsd", total_rounds=args.rounds,
                    warmup_rounds=max(args.rounds // 20, 1))
    engine = FedEngine(task, fed)
    key = jax.random.PRNGKey(0)
    state = engine.init(key)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"model={cfg.name}-reduced  params={n_params / 1e6:.1f}M  "
          f"clients={fed.num_clients} J={fed.local_iters} "
          f"rounds={args.rounds} (WSD schedule)")
    round_fn = jax.jit(engine.round)
    t_start = time.time()
    for r in range(args.rounds):
        batches = syn.make_token_batch(
            jax.random.fold_in(key, 100 + r), fed.num_clients, args.batch,
            args.seq, cfg.vocab_size)
        state, metrics = round_fn(state, batches,
                                  jax.random.fold_in(key, 1000 + r))
        if r % max(args.rounds // 20, 1) == 0 or r == args.rounds - 1:
            print(f"round {r:4d}  loss={float(metrics['loss']):.4f}  "
                  f"lr={float(metrics['lr']):.2e}  "
                  f"({time.time() - t_start:.0f}s)", flush=True)
    if args.ckpt:
        ckpt.save(args.ckpt, state["params"], step=args.rounds,
                  extra={"cfg": cfg.name, "params_m": n_params / 1e6})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
