"""Batched serving example: prefill a batch of prompts, then decode with a
KV cache — the serve_step lowered by the decode_32k / long_500k dry-run
shapes, here at CPU-friendly size.

    PYTHONPATH=src python examples/serve_batched.py --arch chatglm3-6b
    PYTHONPATH=src python examples/serve_batched.py --arch xlstm-1.3b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get_model_config(args.arch).reduced(d_model=128)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only")
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    if cfg.embedding_inputs:
        prompt = {"embeds": jax.random.normal(key, (B, P, cfg.d_model),
                                              dtype=T.param_dtype(cfg))}
    else:
        prompt = {"tokens": jax.random.randint(key, (B, P), 0,
                                               cfg.vocab_size)}

    t0 = time.time()
    logits, cache, _ = T.forward(params, cfg, prompt, want_cache=True,
                                 remat=False)
    cache = T.prefill_to_decode_cache(cfg, cache, P, P + G)
    print(f"prefill {B}x{P}: {time.time() - t0:.2f}s")

    decode = jax.jit(lambda p, b, c, pos: T.decode_step(p, cfg, b, c, pos))
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)
    generated = [tok]
    t0 = time.time()
    for i in range(G - 1):
        if cfg.embedding_inputs:
            nxt = {"embeds": params["embed"][tok][:, None].astype(
                T.param_dtype(cfg))}
        else:
            nxt = {"tokens": tok[:, None]}
        lg, cache = decode(params, nxt, cache, jnp.asarray(P + i, jnp.int32))
        tok = jnp.argmax(lg[:, -1, :cfg.vocab_size], -1)
        generated.append(tok)
    dt = time.time() - t0
    print(f"greedy-decoded {G} x {B} tokens in {dt:.2f}s "
          f"({B * G / max(dt, 1e-9):.1f} tok/s)")
    print("token ids[0]:", [int(t[0]) for t in generated])


if __name__ == "__main__":
    main()
