"""Reproduce Table I: effect of learning rate and local iterations J on
Fed-Sophia test accuracy (FMNIST + CNN).

    PYTHONPATH=src python examples/hyperparam_table.py
"""
from benchmarks import common

print(f"{'lr':>8} {'J':>3} {'test acc':>9}")
for lr in (0.01, 0.003, 0.0005):
    r = common.run_federated("cnn", "fmnist", "fed_sophia", clients=8,
                             rounds=15, local_iters=10, lr=lr)
    print(f"{lr:>8} {10:>3} {r.accs[-1]:>9.3f}")
for J in (1, 5, 10):
    r = common.run_federated("cnn", "fmnist", "fed_sophia", clients=8,
                             rounds=15, local_iters=J, lr=0.001)
    print(f"{0.001:>8} {J:>3} {r.accs[-1]:>9.3f}")
