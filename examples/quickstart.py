"""Quickstart: the paper's core experiment in ~40 lines.

Federated training of an MLP on (synthetic) non-IID MNIST with
Fed-Sophia vs FedAvg — reproduces the Fig. 2 behaviour: Fed-Sophia
reaches the target accuracy in fewer communication rounds.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core.fed import FedEngine
from repro.data import synthetic as syn
from repro.models.small import MLPTask

ROUNDS, CLIENTS = 25, 8

key = jax.random.PRNGKey(0)
x, y = syn.make_image_data(key, 8192, "mnist", noise=1.3)
part = syn.dirichlet_partition(jax.random.fold_in(key, 1), y, CLIENTS,
                               alpha=0.5)
train_idx, test_idx = syn.train_test_split(part)
task = MLPTask(hidden=64)
test_batches = syn.client_batches(jax.random.fold_in(key, 2), x, y,
                                  test_idx, 128)

for optimizer, lr in (("fed_sophia", 0.02), ("fedavg", 0.05)):
    fed = FedConfig(num_clients=CLIENTS, local_iters=10, optimizer=optimizer,
                    lr=lr, tau=5, total_rounds=ROUNDS)
    engine = FedEngine(task, fed)
    state = engine.init(jax.random.fold_in(key, 3))
    round_fn = jax.jit(engine.round)
    print(f"\n== {optimizer} (lr={lr}) ==")
    for r in range(ROUNDS):
        batches = syn.client_batches(jax.random.fold_in(key, 100 + r),
                                     x, y, train_idx, 64)
        state, metrics = round_fn(state, batches,
                                  jax.random.fold_in(key, 1000 + r))
        if r % 5 == 0 or r == ROUNDS - 1:
            acc = jnp.mean(jax.vmap(
                lambda b: task.accuracy(state["params"], b))(test_batches))
            print(f"round {r:3d}  local-loss={float(metrics['loss']):.4f}"
                  f"  test-acc={float(acc):.3f}")
