"""Compressed federated communication: the paper's efficiency axis
made explicit.

Trains the same federated MLP under four regimes — lossless fp32
(identity), unbiased int8 stochastic quantization, top-k
sparsification with error feedback, and the fully bidirectional stack
(int8 uplink + int8 delta-coded broadcast + int4 Hessian-EMA stream) —
and reports test accuracy next to the exact cumulative bytes each
regime put on the wire, all streams, both directions.

    PYTHONPATH=src python examples/comm_compression.py
"""
import jax
import jax.numpy as jnp

from repro.comm import round_bytes
from repro.configs.base import CommConfig, FedConfig
from repro.core.fed import FedEngine
from repro.data import synthetic as syn
from repro.models.small import MLPTask

ROUNDS, CLIENTS = 12, 8

key = jax.random.PRNGKey(0)
x, y = syn.make_image_data(key, 8192, "mnist", noise=1.3)
part = syn.dirichlet_partition(jax.random.fold_in(key, 1), y, CLIENTS,
                               alpha=0.5)
train_idx, test_idx = syn.train_test_split(part)
task = MLPTask(hidden=64)
test_batches = syn.client_batches(jax.random.fold_in(key, 2), x, y,
                                  test_idx, 128)

REGIMES = {
    "identity (fp32)": CommConfig(),
    "int8 stochastic": CommConfig(compressor="int8"),
    "top-k 5% + EF": CommConfig(compressor="topk", topk_ratio=0.05),
    "bidir int8/int8/int4": CommConfig(compressor="int8",
                                       downlink_compressor="int8",
                                       hessian_compressor="int4"),
}

base_total = None
for name, comm in REGIMES.items():
    fed = FedConfig(num_clients=CLIENTS, local_iters=10,
                    optimizer="fed_sophia", lr=0.02, tau=5,
                    total_rounds=ROUNDS, comm=comm)
    engine = FedEngine(task, fed)
    state = engine.init(jax.random.fold_in(key, 3))
    round_fn = jax.jit(engine.round)
    n_params = sum(p.size for p in jax.tree.leaves(state["params"]))
    wire = round_bytes(comm, n_params, CLIENTS)
    per_round = wire["total_bytes"]
    if base_total is None:
        base_total = per_round
    print(f"\n== {name}: {per_round / 2**20:.3f} MiB/round total "
          f"(up {wire['uplink_bytes'] / 2**20:.3f}"
          f" + down {wire['downlink_bytes'] / 2**20:.3f}"
          f" + curv {(wire['hessian_uplink_bytes'] + wire['hessian_downlink_bytes']) / 2**20:.3f};"
          f" {base_total / per_round:.1f}x reduction) ==")
    for r in range(ROUNDS):
        batches = syn.client_batches(jax.random.fold_in(key, 100 + r),
                                     x, y, train_idx, 64)
        state, metrics = round_fn(state, batches,
                                  jax.random.fold_in(key, 1000 + r))
        if r % 4 == 0 or r == ROUNDS - 1:
            acc = jnp.mean(jax.vmap(
                lambda b: task.accuracy(state["params"], b))(test_batches))
            print(f"round {r:3d}  loss={float(metrics['loss']):.4f}"
                  f"  test-acc={float(acc):.3f}"
                  f"  cum-wire={(r + 1) * per_round / 2**20:.2f}MiB")
